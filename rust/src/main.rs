//! `bsf` — CLI launcher for the BSF-skeleton reproduction, built on the
//! unified `Bsf` session API.
//!
//! Subcommands (clap-style; the offline universe has no clap, so
//! `util::cli::ArgMap` supplies the typed option layer):
//!
//! * `run <problem>`     — solve via the session API; `--engine`
//!                          auto|serial|threaded|process|sim picks the
//!                          engine (`process` = real worker OS processes
//!                          over TCP, self-spawned or pre-started via
//!                          `--listen`)
//! * `worker`            — run one worker process: connect to a master,
//!                          announce a rank, drive Algorithm 2's worker
//!                          loop (the distributed-mode child command)
//! * `sim <problem>`     — shorthand for `run --engine sim` (virtual time)
//! * `sweep <problem>`   — two modes: speedup curve over K (model vs
//!                          simulation), or — with `--runs N` — a batch
//!                          sweep expanding a seed grid into N independent
//!                          scheduled jobs, streamed as `bsf-sweep/1` JSONL
//! * `predict <problem>` — calibrate + print the BSF model parameters and
//!                          the predicted scalability boundary
//! * `verify`            — bounded model checking of the message protocol:
//!                          explore every schedule of a small run, check
//!                          deadlock-freedom, tag routing, orphan-freedom
//!                          and schedule determinism
//! * `serve <problem>`   — multi-tenant job scheduler: keep one worker
//!                          fleet alive and multiplex submitted jobs across
//!                          it (control plane over plain HTTP)
//! * `submit <problem>`  — submit one job to a `serve` fleet (`--wait`
//!                          polls until it ends and prints the result)
//! * `jobs`              — list / cancel a `serve` fleet's jobs
//! * `shutdown`          — drain a `serve` fleet and let it exit
//! * `top <addr>`        — live fleet view: poll a running master's
//!                          `/metrics` endpoint (see `--metrics-addr`)
//!                          and render iteration progress, phase ratios
//!                          and per-worker health
//! * `artifacts`         — list the AOT XLA artifacts
//!
//! Problems: `jacobi`, `jacobi-map`, `cimmino`, `gravity`, `montecarlo`,
//! `pagerank`, `kmeans`, `sgd`, `lpp`, `apex`. Common options: `--n`,
//! `--k`, `--omp`, `--seed`,
//! `--eps`, `--profile infiniband|gigabit|ideal`,
//! `--backend native|per-element|xla`.
//!
//! Every failure path is a typed `BsfError`: usage errors exit 2 with
//! help, runtime errors exit 1 — no panics. `--backend xla` degrades to
//! the native map with a warning when the service or artifacts are
//! missing.

use std::sync::Arc;
use std::time::{Duration, Instant};

use bsf::bench::harness as bench_harness;
use bsf::bench::sweep::{print_sweep, speedup_sweep};
use bsf::costmodel::{calibrate, ClusterProfile};
use bsf::error::BsfError;
use bsf::metrics::control::ControlServer;
use bsf::metrics::exporter::{http_get, http_post, MetricsExporter};
use bsf::metrics::telemetry::RunTelemetry;
use bsf::problems::apex::ApexProblem;
use bsf::problems::cimmino::CimminoProblem;
use bsf::problems::gravity::GravityProblem;
use bsf::problems::jacobi::JacobiProblem;
use bsf::problems::jacobi_map::JacobiMapProblem;
use bsf::problems::kmeans::KMeansProblem;
use bsf::problems::lpp::LppProblem;
use bsf::problems::montecarlo::MonteCarloProblem;
use bsf::problems::pagerank::PageRankProblem;
use bsf::problems::sgd::SgdProblem;
use bsf::runtime::backend::{XlaMapBackend, XlaMapSpec};
use bsf::runtime::service::XlaService;
use bsf::runtime::XlaRuntime;
use bsf::skeleton::cluster::{run_persistent_worker, Cluster};
use bsf::skeleton::process::run_process_worker;
use bsf::skeleton::{
    Bsf, BsfConfig, BsfProblem, Checkpoint, ControlApi, FaultPolicy,
    FusedNativeBackend, JobStatus, MapBackend, PerElementBackend, ProcessEngine,
    RunReport, Scheduler, SerialEngine, SimulatedEngine, ThreadedEngine,
};
use bsf::sweep::{run_sweep, HttpControl, SweepSpec};
use bsf::util::cli::ArgMap;
use bsf::util::faultsim::run_flaky_process_worker;
use bsf::util::json::Json;
use bsf::verify::{run_verify, Mutation, VerifyConfig};

const USAGE: &str = "\
usage: bsf <run|worker|sim|sweep|predict|bench|verify|serve|submit|jobs|shutdown|top|artifacts> [problem] [options]

problems: jacobi | jacobi-map | cimmino | gravity | montecarlo | pagerank |
          kmeans | sgd | lpp | apex

options by subcommand:
  run / sim:
    --n N          problem size (default 256)
    --k K          number of workers (default 4; --workers is an alias)
    --threads-per-worker T
                   intra-worker map threads — the paper's OpenMP tier;
                   K workers x T threads is the hybrid two-level grid
                   (default 1; --omp is an alias)
    --seed S       RNG seed (default 7)
    --run-seed S   start from the problem's seeded initial parameter
                   (BsfProblem::seeded_parameter) instead of the default
                   one — the solo twin of a scheduled job's seed field;
                   `bsf sweep --runs N` results byte-compare against this
    --eps E        stop threshold (default 1e-12)
    --trace T      print intermediate results every T iterations
    --max-iter I   iteration cap (default 100000)
    --deadline S   stop after S seconds on the engine's clock (checked
                   between iterations; the running iteration completes)
    --engine E     auto | serial | threaded | process | cluster | sim
                   (run only; cluster = persistent worker pool over TCP,
                   self-spawned or pre-started via --listen + --persist)
    --listen A     with --engine process or cluster: bind A (host:port)
                   and wait for K pre-started `bsf worker` processes
                   instead of self-spawning them on localhost (cluster
                   workers must be started with --persist)
    --metrics-addr A
                   serve live telemetry over HTTP on A (host:port; port
                   0 picks an ephemeral port, printed to stderr as
                   'metrics: listening on ...'): GET /metrics returns
                   the cumulative bsf-metrics/1 snapshot, GET /events
                   the buffered bsf-events/1 stream; poll with
                   `bsf top A`
    --events jsonl stream one bsf-events/1 JSON object per iteration to
                   stderr (stdout stays reserved for results)
    --metrics-interval N
                   emit every Nth iteration event on stderr (default 1;
                   the HTTP endpoints always see every iteration)
    --heartbeat N  workers report health (TAG_HEARTBEAT) every N
                   iterations; 0 disables (default 0, or 8 when
                   telemetry is on)
    --overlap      double-buffered orders: pre-send iteration i+1's
                   order right after deciding iteration i so workers
                   start the next map early; bit-identical results
                   (run only; off by default)
    --fault P      abort | redistribute | restart — what to do when a
                   worker is lost mid-run (default abort; redistribute
                   re-splits over the survivors, restart relaunches at
                   full K from the master's checkpoint)
    --max-losses N with --fault redistribute: losses absorbed per run
                   (default 1)
    --kill-rank R / --kill-after-folds N
                   fault-injection smoke (testing): the spawned worker
                   with rank R hard-exits before sending fold N+1
    --backend B    native | per-element | xla
    --profile P    infiniband | gigabit | ideal    (sim)
    --steps S      leapfrog steps (gravity; default 50)
    --samples S    samples per block (montecarlo; default 10000)
                   (pagerank/kmeans/sgd size off --n like the others:
                   pagerank N nodes in min(N,16) degree-weighted blocks,
                   kmeans N points x 4 clusters, sgd N samples)
  worker (one worker process of a distributed run; ranks 0..K-1,
          the master is rank K — the paper's BC_MpiRun convention):
    --connect A    master address (host:port), required
    --rank R       this worker's rank, required
    --problem P    problem name, required; problem options (--n --seed
                   --eps --steps --samples --threads-per-worker
                   --backend) must match the master's
    --persist      stay alive across runs: serve a persistent cluster
                   (NEWRUN/SHUTDOWN protocol) instead of exiting after
                   one run — the worker side of Cluster::spawn/connect
    --heartbeat N  send a health report every N iterations (must match
                   the master's --heartbeat; the launcher passes it
                   automatically on self-spawned runs)
    --kill-rank R / --kill-after-folds N
                   fault-injection smoke (testing): if R equals this
                   worker's --rank, hard-exit before sending fold N+1
  serve (multi-tenant fleet: accept jobs over HTTP and multiplex them
         across one persistent worker pool; see docs/operations.md):
    <problem>          the one problem this fleet serves (the workers
                       handshake its signature; submissions for any
                       other problem are rejected)
    --workers K        fleet size (default 4; --k is an alias)
    --control A        bind the control endpoint on A (host:port;
                       default 127.0.0.1:0 = ephemeral, printed at
                       startup): POST /jobs, GET /jobs,
                       POST /jobs/<id>/cancel, POST /shutdown,
                       GET /metrics, GET /events
    --listen A         rendezvous with pre-started `bsf worker --persist`
                       processes on A instead of self-spawning them
    problem options (--n --seed --eps --steps --samples
    --threads-per-worker --backend --heartbeat) as under run, plus the
    --kill-rank/--kill-after-folds fault-injection smoke passthrough
  submit (submit one job to a serving fleet):
    <problem>          must equal the problem the fleet serves
    --control A        the fleet's control endpoint (required)
    --workers N|auto   lease size (N >= 1); auto asks the fleet's calibrated cost
                       model for the scalability-boundary K, clamped to
                       free capacity (default: auto)
    --priority P       higher runs first, FIFO within a level (default 0)
    --deadline S       wall-clock budget for the run itself (queue wait
                       excluded)
    --max-iter I       iteration cap (the fleet template's cap still
                       applies; the lower one wins)
    --seed S           start the job from the problem's seeded initial
                       parameter (BsfProblem::seeded_parameter)
    --wait             poll until the job ends and print the same `done:`
                       + `result:` lines a solo `bsf run` prints
    --wait-timeout S   like --wait, but give up (typed error; the job
                       keeps running on the fleet) after S seconds
  jobs (inspect a serving fleet):
    --control A        the fleet's control endpoint (required)
    --json             print the raw bsf-jobs/1 document instead of the
                       rendered table
    --cancel ID        cancel a queued or running job instead of listing
  shutdown (drain a serving fleet and let `bsf serve` exit):
    --control A        the fleet's control endpoint (required)
  sweep (speedup curve, the default mode):
    --n N (default 512)  --k 1,2,4,...  --seed S  --profile P
    --max-iter I (default 30)  --steps S (gravity; default: max-iter)
    --samples S (montecarlo)
  sweep --runs N (batch mode: N independent seeded runs over one fleet,
                  streamed as bsf-sweep/1 JSONL; see docs/workloads.md):
    --runs N           how many independent runs (required for this mode)
    --seed-start S     seed of run 0 (default 1)
    --seed-stride D    seed increment between runs (default 1)
    --workers-per-run k|auto
                       lease size per run (default auto = the fleet's
                       calibrated cost-model K, clamped to free capacity)
    --out FILE         write the JSONL stream to FILE (default: stdout)
    --control A        drive a remote `bsf serve` fleet instead of
                       spawning an embedded one; without it the sweep
                       spins its own fleet (problem options as under
                       serve apply: --n --k --seed --eps ... --listen)
    --max-iter I       per-run iteration cap
    --timeout S        whole-sweep budget: on expiry outstanding runs are
                       cancelled and recorded as failed
  predict:
    --n N (default 512)  --seed S  --profile P
    --steps S (gravity; default 10)  --samples S (montecarlo)
  bench (machine-readable perf sweep; see README 'Benchmark harness'):
    --quick | --full   sweep size (default quick — the CI gate's grid)
    --label L          suite label (default pr)
    --out FILE         write BENCH_<label> JSON to FILE
    --baseline FILE    compare against FILE; exit 1 on iteration drift,
                       missing cases, or wall-clock outside tolerance
    --tolerance X      relative wall-clock band (default 0.25 = ±25%)
    --promote [FILE]   after the sweep (and after --baseline passes, if
                       given), write this run as the measured baseline —
                       to FILE, or over the --baseline path (default
                       BENCH_baseline.json) — replacing a bootstrap
                       document with real timings; refuses unmeasured or
                       grid-incomplete sweeps
  top (live fleet view of a running master; see run --metrics-addr):
    <addr>             the master's metrics address (host:port), printed
                       by `bsf run --metrics-addr` at startup
    --interval S       refresh period in seconds (default 1.0)
    --once             print one snapshot and exit (no screen clearing)
  verify (bounded model checking of the message protocol; see README
          'Verification'):
    --problem P        jacobi | cimmino | pagerank  (default jacobi; the
                       model problem must be small and split-invariant)
    --workers K        model worker count (default 2; the schedule
                       space is exponential in K — keep it small)
    --n N              model problem size (default 12)
    --seed S / --eps E instance seed / stop threshold (default 1e-30 so
                       no schedule converges before the cap)
    --max-iter I       model run length (default 10)
    --max-schedules M  exploration ceiling (default 20000)
    --no-faults        skip the fault-injection schedules
    --mutate M         seed a known bug to prove the checker's teeth:
                       duplicate-fold (worker 0 double-sends a fold;
                       verify must then FAIL)";

/// Options shared by run/sim.
struct Common {
    n: usize,
    seed: u64,
    eps: f64,
    steps: usize,
    samples: usize,
    cfg: BsfConfig,
}

#[derive(Clone, Copy)]
enum EngineOpt {
    Auto,
    Serial,
    Threaded,
    Process,
    Cluster,
    Simulated(ClusterProfile),
}

/// Heartbeat period applied when telemetry is on and `--heartbeat` was
/// not given: frequent enough for a live `bsf top` view, sparse enough
/// to stay invisible next to an order/fold round-trip.
const DEFAULT_HEARTBEAT_EVERY: usize = 8;

#[derive(Clone, Copy, PartialEq)]
enum BackendOpt {
    FusedNative,
    PerElement,
    Xla,
}

fn profile_from(args: &ArgMap) -> Result<ClusterProfile, BsfError> {
    match args.str_or("profile", "infiniband") {
        "infiniband" => Ok(ClusterProfile::infiniband()),
        "gigabit" => Ok(ClusterProfile::gigabit()),
        "ideal" => Ok(ClusterProfile::ideal()),
        other => Err(BsfError::usage(format!(
            "unknown --profile {other:?} (infiniband|gigabit|ideal)"
        ))),
    }
}

fn engine_from(args: &ArgMap) -> Result<EngineOpt, BsfError> {
    match args.str_or("engine", "auto") {
        "auto" => Ok(EngineOpt::Auto),
        "serial" => Ok(EngineOpt::Serial),
        "threaded" => Ok(EngineOpt::Threaded),
        "process" => Ok(EngineOpt::Process),
        "cluster" => Ok(EngineOpt::Cluster),
        "sim" | "simulated" => Ok(EngineOpt::Simulated(profile_from(args)?)),
        other => Err(BsfError::usage(format!(
            "unknown --engine {other:?} (auto|serial|threaded|process|cluster|sim)"
        ))),
    }
}

fn backend_from(args: &ArgMap) -> Result<BackendOpt, BsfError> {
    match args.str_or("backend", "native") {
        "native" | "fused" => Ok(BackendOpt::FusedNative),
        "per-element" => Ok(BackendOpt::PerElement),
        "xla" => Ok(BackendOpt::Xla),
        other => Err(BsfError::usage(format!(
            "unknown --backend {other:?} (native|per-element|xla)"
        ))),
    }
}

fn common_from(args: &ArgMap) -> Result<Common, BsfError> {
    // `--workers` (the distributed-mode spelling) is an alias for `--k`.
    let k = if args.get("workers").is_some() {
        args.usize_or("workers", 4)?
    } else {
        args.usize_or("k", 4)?
    };
    // `--threads-per-worker` (the hybrid-mode spelling) wins over its
    // seed-era alias `--omp`.
    let threads = if args.get("threads-per-worker").is_some() {
        args.usize_or("threads-per-worker", 1)?
    } else {
        args.usize_or("omp", 1)?
    };
    let mut cfg = BsfConfig::with_workers(k)
        .threads_per_worker(threads)
        .trace(args.usize_or("trace", 0)?)
        .max_iter(args.usize_or("max-iter", 100_000)?)
        .heartbeat(args.usize_or("heartbeat", 0)?);
    if args.get("deadline").is_some() {
        let secs = args.f64_or("deadline", 0.0)?;
        // try_from_secs_f64 rejects NaN/infinite/overflowing values, so
        // `--deadline inf` is a typed usage error, never a panic.
        let deadline = if secs >= 0.0 {
            std::time::Duration::try_from_secs_f64(secs).ok()
        } else {
            None
        };
        match deadline {
            Some(d) => cfg.stop.deadline = Some(d),
            None => {
                return Err(BsfError::usage(format!(
                    "--deadline expects a finite non-negative number of seconds, \
                     got {secs}"
                )))
            }
        }
    }
    cfg.fault = match args.str_or("fault", "abort") {
        "abort" => FaultPolicy::Abort,
        "redistribute" => {
            FaultPolicy::Redistribute { max_losses: args.usize_or("max-losses", 1)? }
        }
        "restart" => FaultPolicy::RestartFromCheckpoint,
        other => {
            return Err(BsfError::usage(format!(
                "unknown --fault {other:?} (abort|redistribute|restart)"
            )))
        }
    };
    Ok(Common {
        n: args.usize_or("n", 256)?,
        seed: args.u64_or("seed", 7)?,
        eps: args.f64_or("eps", 1e-12)?,
        steps: args.usize_or("steps", 50)?,
        samples: args.usize_or("samples", 10_000)?,
        cfg,
    })
}

/// Worker argv for a self-spawned distributed run: the same problem and
/// backend the master was asked for, passed explicitly so child defaults
/// can never drift. (`bench::harness::worker_args` builds the same argv
/// from a `BenchCase` — keep the two in lockstep.)
fn worker_args(name: &str, c: &Common, args: &ArgMap) -> Vec<String> {
    let kv: &[(&str, String)] = &[
        ("problem", name.to_string()),
        ("n", c.n.to_string()),
        ("seed", c.seed.to_string()),
        ("eps", c.eps.to_string()),
        ("steps", c.steps.to_string()),
        ("samples", c.samples.to_string()),
        ("threads-per-worker", c.cfg.threads_per_worker.to_string()),
        ("backend", args.str_or("backend", "native").to_string()),
        ("heartbeat", c.cfg.heartbeat_every.to_string()),
    ];
    let mut argv = vec!["worker".to_string()];
    for (k, v) in kv {
        argv.push(format!("--{k}"));
        argv.push(v.clone());
    }
    // Fault-injection passthrough: every spawned worker gets the kill
    // spec; only the one whose --rank matches --kill-rank acts on it.
    if let Some(rank) = args.get("kill-rank") {
        argv.push("--kill-rank".to_string());
        argv.push(rank.to_string());
        argv.push("--kill-after-folds".to_string());
        argv.push(args.str_or("kill-after-folds", "0").to_string());
    }
    argv
}

/// One construction site per problem, shared by the master (`cmd_run`)
/// and worker (`cmd_worker`) paths: a distributed run is undefined unless
/// both rebuild identical instances, so the constructors must never
/// drift apart.
fn mk_jacobi(c: &Common) -> JacobiProblem {
    JacobiProblem::random(c.n, c.eps, c.seed).0
}

fn mk_jacobi_map(c: &Common) -> JacobiMapProblem {
    JacobiMapProblem::random(c.n, c.eps, c.seed).0
}

fn mk_cimmino(c: &Common) -> CimminoProblem {
    CimminoProblem::random(c.n, c.n, c.eps, c.seed).0
}

fn mk_gravity(c: &Common) -> GravityProblem {
    GravityProblem::random(c.n, 1e-3, c.steps, c.seed)
}

fn mk_montecarlo(c: &Common) -> MonteCarloProblem {
    MonteCarloProblem::new(c.n, c.samples, 1e-3)
}

fn mk_pagerank(c: &Common) -> PageRankProblem {
    // The reduce list carries one sparse block per element; cap the
    // block count at 16 so small graphs still split sensibly.
    PageRankProblem::new(c.n, c.n.clamp(1, 16), c.eps, c.seed)
}

fn mk_kmeans(c: &Common) -> KMeansProblem {
    KMeansProblem::new(c.n, 4, c.eps, c.seed)
}

fn mk_sgd(c: &Common) -> SgdProblem {
    SgdProblem::new(c.n, c.eps, c.seed)
}

fn mk_lpp(c: &Common) -> LppProblem {
    LppProblem::random(4 * c.n, c.n, c.seed)
}

fn mk_apex(c: &Common) -> ApexProblem {
    ApexProblem::random(4 * c.n, c.n, c.seed)
}

fn apply_engine<P: BsfProblem>(
    b: Bsf<P>,
    engine: EngineOpt,
    args: &ArgMap,
    name: &str,
    c: &Common,
) -> Bsf<P> {
    match engine {
        EngineOpt::Auto => b,
        EngineOpt::Serial => b.engine(SerialEngine),
        EngineOpt::Threaded => b.engine(ThreadedEngine),
        EngineOpt::Process => match args.get("listen") {
            Some(addr) => b.engine(ProcessEngine::listen(addr)),
            None => b.engine(ProcessEngine::spawn_args(worker_args(name, c, args))),
        },
        // Unreachable from cmd_run — run_problem intercepts the cluster
        // engine (ClusterSpec::start needs the problem instance).
        EngineOpt::Cluster => b,
        EngineOpt::Simulated(profile) => b.engine(SimulatedEngine::new(profile)),
    }
}

/// Start the XLA service, or warn and fall back to the native map
/// (missing artifacts or a backend-less build must degrade, not panic).
fn start_xla_or_warn() -> Option<XlaService> {
    if !XlaRuntime::backend_available() {
        eprintln!(
            "bsf: warning: no PJRT backend linked into this build \
             (see runtime::pjrt); falling back to the native map"
        );
        return None;
    }
    match XlaService::start_default() {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!(
                "bsf: warning: XLA backend unavailable ({e}); \
                 falling back to the native map"
            );
            None
        }
    }
}

/// Attach the chosen backend to a session over an XLA-capable problem.
fn attach_xla_capable<P: XlaMapSpec>(
    b: Bsf<P>,
    backend: BackendOpt,
    service: &Option<XlaService>,
) -> Bsf<P> {
    match backend {
        BackendOpt::FusedNative => b,
        BackendOpt::PerElement => b.map_backend(PerElementBackend),
        BackendOpt::Xla => match service {
            Some(s) => b.map_backend(XlaMapBackend::new(s.handle())),
            None => b, // warning already printed by start_xla_or_warn
        },
    }
}

/// Attach the chosen backend to a session over a problem without AOT
/// artifacts (xla degrades to native with a note).
fn attach_native_only<P: BsfProblem>(b: Bsf<P>, backend: BackendOpt, name: &str) -> Bsf<P> {
    match backend {
        BackendOpt::FusedNative => b,
        BackendOpt::PerElement => b.map_backend(PerElementBackend),
        BackendOpt::Xla => {
            eprintln!(
                "bsf: warning: {name} has no AOT artifacts; using the native map"
            );
            b
        }
    }
}

/// Result describers shared by `cmd_run`, `cmd_serve` and the embedded
/// sweep: the same closure renders a solo run's `result:` line, a
/// scheduled job's `result` field and a sweep record's `result` field,
/// so the three are byte-comparable (the sweep-smoke CI job does exactly
/// that).
fn describe_montecarlo(t: &(u64, u64, u64)) -> String {
    format!("pi ≈ {:.6} ({} samples)", MonteCarloProblem::estimate(t), t.2)
}

fn describe_pagerank(x: &[f64]) -> String {
    let (node, score) = PageRankProblem::top(x);
    format!("top node {node} (rank {score:.6}); {}", head(x))
}

fn head(xs: &[f64]) -> String {
    let k = xs.len().min(4);
    let parts: Vec<String> = xs[..k].iter().map(|v| format!("{v:.6}")).collect();
    format!(
        "[{}{}] (n={})",
        parts.join(", "),
        if xs.len() > k { ", ..." } else { "" },
        xs.len()
    )
}

fn finish<Param>(
    r: RunReport<Param>,
    describe: impl Fn(&Param) -> String,
) -> Result<(), BsfError> {
    // stdout carries results only (`done:` + `result:`), so piped output
    // stays machine-parseable; diagnostics go to stderr.
    println!("done: {}", r.summary_without_losses());
    eprintln!("phases: {}", r.phases.summary());
    let traffic = r.transport_summary();
    if !traffic.is_empty() {
        eprintln!("traffic: {traffic}");
    }
    let hybrid = r.hybrid_summary();
    if !hybrid.is_empty() {
        eprintln!("hybrid: {hybrid}");
    }
    if !r.losses.is_empty() {
        let ranks: Vec<String> = r.losses.iter().map(|r| r.to_string()).collect();
        eprintln!("lost={}", ranks.join(","));
    }
    if !r.rejoined.is_empty() {
        let ranks: Vec<String> = r.rejoined.iter().map(|r| r.to_string()).collect();
        eprintln!("rejoined={}", ranks.join(","));
    }
    // Best-effort release/unpark sends that failed (recorded instead of
    // silently swallowed): diagnostics, so stderr like the rest.
    let teardown = r.teardown_summary();
    if !teardown.is_empty() {
        eprintln!("{teardown}");
    }
    println!("result: {}", describe(&r.param));
    Ok(())
}

const RUN_OPTS: &[&str] = &[
    "n", "k", "workers", "omp", "threads-per-worker", "seed", "run-seed", "eps",
    "trace", "max-iter", "deadline", "engine", "backend", "profile", "steps",
    "samples", "listen", "fault", "max-losses", "kill-rank", "kill-after-folds",
    "metrics-addr", "metrics-interval", "events", "heartbeat", "overlap",
];

/// Run one problem to completion under the chosen engine. The
/// persistent-cluster engine can't go through `apply_engine` —
/// `ClusterSpec::start` needs the problem instance to handshake the
/// worker pool — so it is wired here; every other engine defers to
/// `apply_engine`. When live telemetry is attached, the cost model is
/// calibrated first so `/metrics` and the event stream carry
/// predicted-vs-measured phase seconds.
fn run_problem<P: BsfProblem>(
    p: P,
    engine: EngineOpt,
    args: &ArgMap,
    name: &str,
    c: &Common,
    attach: impl FnOnce(Bsf<P>) -> Bsf<P>,
) -> Result<RunReport<P::Param>, BsfError> {
    if let Some(t) = &c.cfg.telemetry {
        let cal = calibrate(&p, profile_from(args)?, 3);
        t.set_cost_model(&cal.params, c.cfg.workers.max(1));
    }
    // `--run-seed S` starts from the problem's seeded initial parameter
    // via the iteration-0 checkpoint path — the solo twin of a scheduled
    // job's `seed` field, so sweep results byte-compare against it.
    let start = match args.get("run-seed") {
        None => None,
        Some(_) => {
            let s = args.u64_or("run-seed", 0)?;
            Some(Checkpoint { param: p.seeded_parameter(s), iter: 0, job: 0 })
        }
    };
    if matches!(engine, EngineOpt::Cluster) {
        let spec = match args.get("listen") {
            Some(addr) => Cluster::connect(c.cfg.workers, addr),
            None => Cluster::spawn(c.cfg.workers, worker_args(name, c, args)),
        };
        let cluster = spec.start(&p)?;
        let mut session =
            attach(Bsf::new(p).config(c.cfg.clone()).engine(cluster.engine()));
        if let Some(ck) = start {
            session = session.resume(ck);
        }
        let report = session.run()?;
        cluster.shutdown()?;
        Ok(report)
    } else {
        let mut session =
            attach(apply_engine(Bsf::new(p).config(c.cfg.clone()), engine, args, name, c));
        if let Some(ck) = start {
            session = session.resume(ck);
        }
        session.run()
    }
}

fn cmd_run(args: &ArgMap, engine: EngineOpt) -> Result<(), BsfError> {
    args.ensure_known(RUN_OPTS)?;
    // --listen only means something to the engines that bind a TCP
    // rendezvous; anywhere else it would be silently ignored while
    // remote workers wait forever.
    if args.get("listen").is_some()
        && !matches!(engine, EngineOpt::Process | EngineOpt::Cluster)
    {
        return Err(BsfError::usage(
            "--listen requires --engine process or cluster (it binds the \
             master's address for pre-started `bsf worker` processes)",
        ));
    }
    let mut c = common_from(args)?;
    c.cfg.overlap = args.flag("overlap");

    // Live telemetry: `--events jsonl` streams schema-versioned
    // iteration events to stderr (stdout stays reserved for results);
    // `--metrics-addr` additionally serves GET /metrics + /events over
    // HTTP for `bsf top`. The exporter must outlive the run, so it is
    // held here until cmd_run returns.
    let events_jsonl = match args.get("events") {
        None => false,
        Some("jsonl") => true,
        Some(other) => {
            return Err(BsfError::usage(format!("unknown --events {other:?} (jsonl)")))
        }
    };
    let metrics_interval = args.usize_or("metrics-interval", 1)?.max(1);
    let mut _exporter: Option<MetricsExporter> = None;
    if events_jsonl || args.get("metrics-addr").is_some() {
        let mut sink = RunTelemetry::new();
        if events_jsonl {
            sink = sink.events_to_stderr(metrics_interval as u64);
        }
        let sink = Arc::new(sink);
        if args.get("heartbeat").is_none() {
            // Live worker health needs beats; default them on with
            // telemetry (explicit --heartbeat 0 still disables).
            c.cfg.heartbeat_every = DEFAULT_HEARTBEAT_EVERY;
        }
        if let Some(addr) = args.get("metrics-addr") {
            let exp = MetricsExporter::bind(addr, Arc::clone(&sink))?;
            eprintln!(
                "metrics: listening on {} (GET /metrics, GET /events)",
                exp.addr()
            );
            _exporter = Some(exp);
        }
        c.cfg.telemetry = Some(sink);
    }

    let backend = backend_from(args)?;
    // One service outlives the whole run (worker handles clone from it).
    let service = if backend == BackendOpt::Xla {
        start_xla_or_warn()
    } else {
        None
    };
    let name = args.positional(0).unwrap_or("jacobi");
    match name {
        "jacobi" => finish(
            run_problem(mk_jacobi(&c), engine, args, name, &c, |b| {
                attach_xla_capable(b, backend, &service)
            })?,
            |x| head(x),
        ),
        "jacobi-map" => finish(
            run_problem(mk_jacobi_map(&c), engine, args, name, &c, |b| {
                attach_xla_capable(b, backend, &service)
            })?,
            |x| head(x),
        ),
        "cimmino" => finish(
            run_problem(mk_cimmino(&c), engine, args, name, &c, |b| {
                attach_xla_capable(b, backend, &service)
            })?,
            |x| head(x),
        ),
        "gravity" => finish(
            run_problem(mk_gravity(&c), engine, args, name, &c, |b| {
                attach_xla_capable(b, backend, &service)
            })?,
            |x| head(x),
        ),
        "montecarlo" => finish(
            run_problem(mk_montecarlo(&c), engine, args, name, &c, |b| {
                attach_native_only(b, backend, "montecarlo")
            })?,
            describe_montecarlo,
        ),
        "pagerank" => finish(
            run_problem(mk_pagerank(&c), engine, args, name, &c, |b| {
                attach_native_only(b, backend, "pagerank")
            })?,
            |x| describe_pagerank(x),
        ),
        "kmeans" => {
            let probe = mk_kmeans(&c);
            finish(
                run_problem(mk_kmeans(&c), engine, args, name, &c, |b| {
                    attach_native_only(b, backend, "kmeans")
                })?,
                move |x| format!("inertia {:.6}; {}", probe.inertia(x), head(x)),
            )
        }
        "sgd" => {
            let probe = mk_sgd(&c);
            finish(
                run_problem(mk_sgd(&c), engine, args, name, &c, |b| {
                    attach_native_only(b, backend, "sgd")
                })?,
                move |p| format!("loss {:.6}; w = {}", probe.loss(p), head(&p.1)),
            )
        }
        "lpp" => finish(
            run_problem(mk_lpp(&c), engine, args, name, &c, |b| {
                attach_native_only(b, backend, "lpp")
            })?,
            |x| head(x),
        ),
        "apex" => finish(
            run_problem(mk_apex(&c), engine, args, name, &c, |b| {
                attach_native_only(b, backend, "apex")
            })?,
            |(x, _)| head(x),
        ),
        other => Err(BsfError::usage(format!("unknown problem {other:?}"))),
    }
}

const WORKER_OPTS: &[&str] = &[
    "connect", "rank", "problem", "n", "seed", "eps", "steps", "samples", "omp",
    "threads-per-worker", "backend", "persist", "fault", "max-losses", "kill-rank",
    "kill-after-folds", "heartbeat",
];

/// One worker process of a distributed run (the child side of
/// `--engine process`, or a hand-started remote worker). Rebuilds the
/// same problem instance the master holds from the same options, then
/// drives the shared Algorithm-2 worker loop over TCP.
fn cmd_worker(args: &ArgMap) -> Result<(), BsfError> {
    args.ensure_known(WORKER_OPTS)?;
    let connect = args
        .get("connect")
        .ok_or_else(|| BsfError::usage("worker requires --connect <host:port>"))?;
    let rank = match args.get("rank") {
        Some(v) => v
            .parse::<usize>()
            .map_err(|_| BsfError::usage(format!("--rank expects an integer, got {v:?}")))?,
        None => return Err(BsfError::usage("worker requires --rank <r>")),
    };
    let name = args
        .get("problem")
        .ok_or_else(|| BsfError::usage("worker requires --problem <name>"))?;
    let c = common_from(args)?;
    let backend = backend_from(args)?;
    // --persist: serve a persistent cluster (NEWRUN/SHUTDOWN) instead
    // of exiting after one run.
    let persist = args.flag("persist");
    // Fault-injection smoke: die before sending fold N+1, but only when
    // the kill spec names *this* worker's rank (the launcher passes the
    // same argv to every spawned child).
    let die: Option<usize> = match args.get("kill-rank") {
        Some(v) if v.parse::<usize>().ok() == Some(rank) => {
            Some(args.usize_or("kill-after-folds", 0)?)
        }
        _ => None,
    };

    fn drive<P: BsfProblem>(
        p: &P,
        b: &dyn MapBackend<P>,
        connect: &str,
        rank: usize,
        cfg: &BsfConfig,
        persist: bool,
        die: Option<usize>,
    ) -> Result<(), BsfError> {
        match die {
            Some(budget) => {
                run_flaky_process_worker(p, b, connect, rank, cfg, budget, persist)
            }
            None if persist => run_persistent_worker(p, b, connect, rank, cfg),
            None => run_process_worker(p, b, connect, rank, cfg).map(|_| ()),
        }
    }

    fn go<P: BsfProblem>(
        p: &P,
        backend: BackendOpt,
        connect: &str,
        rank: usize,
        cfg: &BsfConfig,
        persist: bool,
        die: Option<usize>,
    ) -> Result<(), BsfError> {
        match backend {
            BackendOpt::PerElement => {
                drive(p, &PerElementBackend, connect, rank, cfg, persist, die)
            }
            BackendOpt::Xla => {
                eprintln!(
                    "bsf: warning: worker processes use the native map \
                     (--backend xla is master-side only); using native"
                );
                drive(p, &FusedNativeBackend, connect, rank, cfg, persist, die)
            }
            BackendOpt::FusedNative => {
                drive(p, &FusedNativeBackend, connect, rank, cfg, persist, die)
            }
        }
    }

    // The mk_* constructors are shared with cmd_run, so worker j holds
    // the same problem instance as the master by construction.
    match name {
        "jacobi" => go(&mk_jacobi(&c), backend, connect, rank, &c.cfg, persist, die),
        "jacobi-map" => {
            go(&mk_jacobi_map(&c), backend, connect, rank, &c.cfg, persist, die)
        }
        "cimmino" => go(&mk_cimmino(&c), backend, connect, rank, &c.cfg, persist, die),
        "gravity" => go(&mk_gravity(&c), backend, connect, rank, &c.cfg, persist, die),
        "montecarlo" => {
            go(&mk_montecarlo(&c), backend, connect, rank, &c.cfg, persist, die)
        }
        "pagerank" => go(&mk_pagerank(&c), backend, connect, rank, &c.cfg, persist, die),
        "kmeans" => go(&mk_kmeans(&c), backend, connect, rank, &c.cfg, persist, die),
        "sgd" => go(&mk_sgd(&c), backend, connect, rank, &c.cfg, persist, die),
        "lpp" => go(&mk_lpp(&c), backend, connect, rank, &c.cfg, persist, die),
        "apex" => go(&mk_apex(&c), backend, connect, rank, &c.cfg, persist, die),
        other => Err(BsfError::usage(format!("unknown problem {other:?} (worker)"))),
    }
}

const SERVE_OPTS: &[&str] = &[
    "n", "k", "workers", "omp", "threads-per-worker", "seed", "eps", "trace",
    "max-iter", "deadline", "backend", "profile", "steps", "samples", "listen",
    "control", "heartbeat", "kill-rank", "kill-after-folds",
];

/// `bsf serve`: start a persistent fleet for one problem and multiplex
/// submitted jobs across it until a control client asks for shutdown.
/// The scheduler and control plane live in the library
/// (`skeleton::scheduler`, `metrics::control`); this wires them to the
/// CLI's problem constructors and result describers.
fn cmd_serve(args: &ArgMap) -> Result<(), BsfError> {
    args.ensure_known(SERVE_OPTS)?;
    let c = common_from(args)?;
    if c.cfg.workers == 0 {
        return Err(BsfError::usage("serve needs at least one worker"));
    }
    let name = args.positional(0).unwrap_or("jacobi");
    match name {
        "jacobi" => serve_problem(mk_jacobi(&c), args, name, &c, |x| head(x)),
        "jacobi-map" => serve_problem(mk_jacobi_map(&c), args, name, &c, |x| head(x)),
        "cimmino" => serve_problem(mk_cimmino(&c), args, name, &c, |x| head(x)),
        "gravity" => serve_problem(mk_gravity(&c), args, name, &c, |x| head(x)),
        "montecarlo" => {
            serve_problem(mk_montecarlo(&c), args, name, &c, describe_montecarlo)
        }
        "pagerank" => {
            serve_problem(mk_pagerank(&c), args, name, &c, |x| describe_pagerank(x))
        }
        "kmeans" => {
            let probe = mk_kmeans(&c);
            serve_problem(mk_kmeans(&c), args, name, &c, move |x| {
                format!("inertia {:.6}; {}", probe.inertia(x), head(x))
            })
        }
        "sgd" => {
            let probe = mk_sgd(&c);
            serve_problem(mk_sgd(&c), args, name, &c, move |p| {
                format!("loss {:.6}; w = {}", probe.loss(p), head(&p.1))
            })
        }
        "lpp" => serve_problem(mk_lpp(&c), args, name, &c, |x| head(x)),
        "apex" => serve_problem(mk_apex(&c), args, name, &c, |(x, _)| head(x)),
        other => Err(BsfError::usage(format!("unknown problem {other:?} (serve)"))),
    }
}

/// The generic body of `bsf serve`: fleet up, scheduler + control
/// endpoint up, drain on request, fleet down. The describer closure is
/// the same one `cmd_run` passes to `finish`, so a scheduled job's
/// `result` field is byte-identical to a solo run's `result:` line.
fn serve_problem<P: BsfProblem>(
    p: P,
    args: &ArgMap,
    name: &str,
    c: &Common,
    describe: impl Fn(&P::Param) -> String + Send + Sync + 'static,
) -> Result<(), BsfError> {
    // Calibrate first: `--workers auto` submissions resolve to the
    // model's scalability-boundary K instead of the whole free set, and
    // /metrics carries predicted-vs-measured phase seconds.
    let cal = calibrate(&p, profile_from(args)?, 3);
    let sink = Arc::new(RunTelemetry::new());
    sink.run_start("cluster", c.cfg.workers);
    sink.set_cost_model(&cal.params, c.cfg.workers.max(1));

    let spec = match args.get("listen") {
        Some(addr) => Cluster::connect(c.cfg.workers, addr),
        None => Cluster::spawn(c.cfg.workers, worker_args(name, c, args)),
    };
    let cluster = spec.start(&p)?;
    let sched = Arc::new(
        Scheduler::new(cluster.pool(), Arc::new(p), name, c.cfg.clone())
            .describe_with(describe)
            .cost_model(cal.params)
            .telemetry(Arc::clone(&sink)),
    );
    let server = ControlServer::bind(
        args.str_or("control", "127.0.0.1:0"),
        Arc::new(Arc::clone(&sched)) as Arc<dyn ControlApi>,
    )?;
    // The one line scripts parse (stdout): where the control plane is.
    println!(
        "serve: {name} fleet of {} worker(s); control on {}",
        c.cfg.workers,
        server.addr()
    );
    eprintln!(
        "control: POST /jobs, GET /jobs, POST /jobs/<id>/cancel, POST /shutdown, \
         GET /metrics, GET /events — or bsf submit/jobs/shutdown --control {}",
        server.addr()
    );

    // Serve until a control client POSTs /shutdown, then drain what is
    // queued or running and tear the fleet down. Between control polls
    // the idle ranks are probed (FLEET_PING/PONG) so a silently dead
    // worker is retired before it can be leased to a tenant — without
    // the probe it would only be discovered when a lease's NEWRUN
    // handshake fails, retiring healthy lease members with it.
    const PROBE_INTERVAL: Duration = Duration::from_secs(2);
    let mut last_probe = Instant::now();
    while !sched.is_draining() {
        std::thread::sleep(Duration::from_millis(100));
        if last_probe.elapsed() >= PROBE_INTERVAL {
            if let Err(e) = sched.probe_idle() {
                eprintln!("serve: idle probe failed: {e}");
            }
            last_probe = Instant::now();
        }
    }
    eprintln!("serve: draining ({} job(s) pending)", sched.queue_depth());
    while !sched.wait_idle(Duration::from_secs(60)) {}
    server.shutdown();
    let ledger = sched.jobs();
    cluster.shutdown()?;
    let count = |s: JobStatus| ledger.iter().filter(|j| j.status == s).count();
    println!(
        "done: served {} job(s) ({} done, {} cancelled, {} failed)",
        ledger.len(),
        count(JobStatus::Done),
        count(JobStatus::Cancelled),
        count(JobStatus::Failed),
    );
    Ok(())
}

/// Timeout for one control-plane HTTP exchange (`bsf submit` / `jobs` /
/// `shutdown` → `bsf serve`).
const CONTROL_TIMEOUT: Duration = Duration::from_secs(10);

fn control_addr(args: &ArgMap) -> Result<&str, BsfError> {
    args.get("control").ok_or_else(|| {
        BsfError::usage(
            "this subcommand talks to a `bsf serve` control endpoint — pass \
             --control <host:port> (printed by `bsf serve` at startup)",
        )
    })
}

const SUBMIT_OPTS: &[&str] = &[
    "control", "workers", "k", "priority", "deadline", "max-iter", "seed", "wait",
    "wait-timeout",
];

/// `bsf submit`: POST one job contract to a serving fleet. With
/// `--wait` (or `--wait-timeout S`, which implies it), poll until the
/// job is terminal and print the same `done:` + `result:` lines a solo
/// `bsf run` would.
fn cmd_submit(args: &ArgMap) -> Result<(), BsfError> {
    args.ensure_known(SUBMIT_OPTS)?;
    let addr = control_addr(args)?;
    let name = args.positional(0).ok_or_else(|| {
        BsfError::usage("submit requires a problem name (the one the fleet serves)")
    })?;
    let mut fields = vec![("problem", Json::Str(name.to_string()))];
    match args.get("workers").or_else(|| args.get("k")) {
        None => {}
        Some("auto") => fields.push(("workers", Json::Str("auto".into()))),
        Some(v) => {
            let k: u64 = v.parse().map_err(|_| {
                BsfError::usage(format!(
                    "--workers expects an integer or \"auto\", got {v:?}"
                ))
            })?;
            if k == 0 {
                return Err(BsfError::usage(
                    "--workers must be >= 1 (use \"auto\" for the cost-model K)",
                ));
            }
            fields.push(("workers", Json::Num(k as f64)));
        }
    }
    if args.get("priority").is_some() {
        fields.push(("priority", Json::Num(args.f64_or("priority", 0.0)?)));
    }
    if args.get("deadline").is_some() {
        let secs = args.f64_or("deadline", 0.0)?;
        if !secs.is_finite() || secs < 0.0 {
            return Err(BsfError::usage(format!(
                "--deadline expects a finite non-negative number of seconds, \
                 got {secs}"
            )));
        }
        fields.push(("deadline_secs", Json::Num(secs)));
    }
    if args.get("max-iter").is_some() {
        fields.push(("max_iter", Json::Num(args.usize_or("max-iter", 0)? as f64)));
    }
    if args.get("seed").is_some() {
        fields.push(("seed", Json::Num(args.u64_or("seed", 0)? as f64)));
    }
    let wait_timeout = match args.get("wait-timeout") {
        None => None,
        Some(_) => {
            let secs = args.f64_or("wait-timeout", 0.0)?;
            // try_from_secs_f64 rejects NaN/infinite/overflowing values.
            match Duration::try_from_secs_f64(secs) {
                Ok(d) if secs > 0.0 => Some(d),
                _ => {
                    return Err(BsfError::usage(format!(
                        "--wait-timeout expects a finite positive number of \
                         seconds, got {secs}"
                    )))
                }
            }
        }
    };
    let body = Json::obj(fields).pretty();
    let resp = http_post(addr, "/jobs", &body, CONTROL_TIMEOUT)?;
    let doc = Json::parse(&resp)
        .map_err(|e| BsfError::transport(format!("bad submit response from {addr}: {e}")))?;
    let id = doc
        .get("id")
        .and_then(Json::as_u64)
        .ok_or_else(|| BsfError::transport(format!("submit response has no id: {resp}")))?;
    if !args.flag("wait") && wait_timeout.is_none() {
        println!("submitted: job {id} ({name}) — poll with `bsf jobs --control {addr}`");
        return Ok(());
    }
    wait_for_job(addr, id, wait_timeout)
}

/// Poll `GET /jobs` until job `id` is terminal, or `timeout` (when
/// given) passes — a wedged fleet must not hang `bsf submit --wait`
/// forever. The printed `result:` line is the byte-compare artifact
/// for scheduled-vs-solo runs.
fn wait_for_job(addr: &str, id: u64, timeout: Option<Duration>) -> Result<(), BsfError> {
    let started = Instant::now();
    loop {
        let body = http_get(addr, "/jobs", CONTROL_TIMEOUT)?;
        let doc = Json::parse(&body)
            .map_err(|e| BsfError::transport(format!("bad /jobs JSON from {addr}: {e}")))?;
        let row = doc
            .get("jobs")
            .and_then(Json::as_arr)
            .and_then(|rows| {
                rows.iter().find(|r| r.get("id").and_then(Json::as_u64) == Some(id))
            })
            .ok_or_else(|| {
                BsfError::transport(format!("job {id} vanished from {addr}/jobs"))
            })?;
        match row.get("status").and_then(Json::as_str).unwrap_or("?") {
            "done" => {
                println!(
                    "done: job {id} finished after {} iteration(s) in {:.6}s",
                    row.get("iterations").and_then(Json::as_u64).unwrap_or(0),
                    row.get("elapsed").and_then(Json::as_f64).unwrap_or(0.0),
                );
                if let Some(result) = row.get("result").and_then(Json::as_str) {
                    println!("result: {result}");
                }
                return Ok(());
            }
            "cancelled" => {
                println!("done: job {id} cancelled");
                return Ok(());
            }
            "failed" => {
                let err =
                    row.get("error").and_then(Json::as_str).unwrap_or("unknown error");
                return Err(BsfError::config(format!("job {id} failed: {err}")));
            }
            status => {
                if let Some(t) = timeout {
                    if started.elapsed() >= t {
                        return Err(BsfError::config(format!(
                            "gave up on job {id} after {:.1}s (--wait-timeout): \
                             still {status}; it keeps running on the fleet — \
                             poll `bsf jobs --control {addr}` or cancel it with \
                             `bsf jobs --control {addr} --cancel {id}`",
                            t.as_secs_f64()
                        )));
                    }
                }
                std::thread::sleep(Duration::from_millis(200));
            }
        }
    }
}

const JOBS_OPTS: &[&str] = &["control", "cancel", "json"];

/// `bsf jobs`: list (or `--cancel`) the jobs of a serving fleet.
fn cmd_jobs(args: &ArgMap) -> Result<(), BsfError> {
    args.ensure_known(JOBS_OPTS)?;
    let addr = control_addr(args)?;
    if let Some(v) = args.get("cancel") {
        let id: u64 = v.parse().map_err(|_| {
            BsfError::usage(format!("--cancel expects a job id, got {v:?}"))
        })?;
        let resp = http_post(addr, &format!("/jobs/{id}/cancel"), "", CONTROL_TIMEOUT)?;
        let doc = Json::parse(&resp).map_err(|e| {
            BsfError::transport(format!("bad cancel response from {addr}: {e}"))
        })?;
        println!(
            "cancel: job {id} was {}",
            doc.get("status").and_then(Json::as_str).unwrap_or("?")
        );
        return Ok(());
    }
    let body = http_get(addr, "/jobs", CONTROL_TIMEOUT)?;
    if args.flag("json") {
        println!("{}", body.trim_end());
        return Ok(());
    }
    let doc = Json::parse(&body)
        .map_err(|e| BsfError::transport(format!("bad /jobs JSON from {addr}: {e}")))?;
    print!("{}", render_jobs(addr, &doc));
    Ok(())
}

/// Render one `bsf-jobs/1` document as the `bsf jobs` table. Tolerant
/// of missing fields, like `render_top`: a newer server never crashes
/// an older viewer.
fn render_jobs(addr: &str, doc: &Json) -> String {
    let fleet = doc.get("fleet");
    let fnum = |k: &str| fleet.and_then(|f| f.get(k)).and_then(Json::as_u64).unwrap_or(0);
    let ranks = |v: Option<&Json>| -> String {
        v.and_then(Json::as_arr)
            .map(|xs| {
                xs.iter()
                    .filter_map(Json::as_u64)
                    .map(|r| r.to_string())
                    .collect::<Vec<_>>()
                    .join(",")
            })
            .unwrap_or_default()
    };
    let mut out = String::new();
    out.push_str(&format!(
        "bsf jobs — {addr} problem={} fleet={} free={} active={} lost=[{}] queue={}\n",
        doc.get("problem").and_then(Json::as_str).unwrap_or("?"),
        fnum("spawn_k"),
        fnum("free"),
        fnum("active_jobs"),
        ranks(fleet.and_then(|f| f.get("lost"))),
        doc.get("queue_depth").and_then(Json::as_u64).unwrap_or(0),
    ));
    let rows = match doc.get("jobs").and_then(Json::as_arr) {
        Some(rows) if !rows.is_empty() => rows,
        _ => {
            out.push_str("(no jobs submitted yet)\n");
            return out;
        }
    };
    out.push_str(
        "id    status     pri   req  granted     iters    elapsed(s)  result\n",
    );
    for row in rows {
        let num = |k: &str| row.get(k).and_then(Json::as_u64).unwrap_or(0);
        // Failed jobs show their error where others show their result.
        let outcome = row
            .get("error")
            .and_then(Json::as_str)
            .or_else(|| row.get("result").and_then(Json::as_str))
            .unwrap_or("-");
        out.push_str(&format!(
            "{:<6}{:<11}{:<6}{:<5}{:<12}{:<9}{:<12.6}{}\n",
            num("id"),
            row.get("status").and_then(Json::as_str).unwrap_or("?"),
            row.get("priority").and_then(Json::as_f64).unwrap_or(0.0) as i64,
            num("requested"),
            format!("[{}]", ranks(row.get("granted"))),
            num("iterations"),
            row.get("elapsed").and_then(Json::as_f64).unwrap_or(0.0),
            outcome,
        ));
    }
    out
}

/// `bsf shutdown`: ask a serving fleet to drain and exit.
fn cmd_shutdown(args: &ArgMap) -> Result<(), BsfError> {
    args.ensure_known(&["control"])?;
    let addr = control_addr(args)?;
    let resp = http_post(addr, "/shutdown", "", CONTROL_TIMEOUT)?;
    let doc = Json::parse(&resp)
        .map_err(|e| BsfError::transport(format!("bad shutdown response from {addr}: {e}")))?;
    println!(
        "shutdown: {} — the fleet exits once the queue drains",
        doc.get("status").and_then(Json::as_str).unwrap_or("?")
    );
    Ok(())
}

fn cmd_sweep(args: &ArgMap) -> Result<(), BsfError> {
    // `--runs N` selects the batch mode (N independent seeded jobs over
    // one fleet, streamed as bsf-sweep/1 JSONL); without it this is the
    // seed-era speedup-curve sweep over K.
    if args.get("runs").is_some() {
        return cmd_sweep_batch(args);
    }
    args.ensure_known(&["n", "k", "seed", "profile", "max-iter", "samples", "steps"])?;
    let n = args.usize_or("n", 512)?;
    let seed = args.u64_or("seed", 7)?;
    let profile = profile_from(args)?;
    let ks = args.usize_list_or("k", &[1, 2, 4, 8, 16, 32, 64, 128, 256])?;
    let max_iter = args.usize_or("max-iter", 30)?;
    let samples = args.usize_or("samples", 10_000)?;
    // Gravity stops after `steps` leapfrog iterations; default to the
    // sweep's iteration budget so runs don't end early.
    let steps = args.usize_or("steps", max_iter)?;
    let name = args.positional(0).unwrap_or("jacobi");

    let sweep = match name {
        "jacobi" => {
            speedup_sweep(|| JacobiProblem::random(n, 1e-30, seed).0, &ks, profile, max_iter)?
        }
        "jacobi-map" => speedup_sweep(
            || JacobiMapProblem::random(n, 1e-30, seed).0,
            &ks,
            profile,
            max_iter,
        )?,
        "cimmino" => speedup_sweep(
            || CimminoProblem::random(n, n, 1e-30, seed).0,
            &ks,
            profile,
            max_iter,
        )?,
        "gravity" => speedup_sweep(
            || GravityProblem::random(n, 1e-3, steps, seed),
            &ks,
            profile,
            max_iter,
        )?,
        "montecarlo" => speedup_sweep(
            || MonteCarloProblem::new(n, samples, 1e-12),
            &ks,
            profile,
            max_iter,
        )?,
        other => return Err(BsfError::usage(format!("unknown problem {other:?} (sweep)"))),
    };
    print_sweep(&format!("sweep {name} n={n}"), &sweep);
    Ok(())
}

const SWEEP_BATCH_OPTS: &[&str] = &[
    "runs", "seed-start", "seed-stride", "workers-per-run", "control", "out",
    "timeout",
    // Embedded-fleet options, as under serve (ignored with --control):
    "n", "k", "workers", "omp", "threads-per-worker", "seed", "eps", "trace",
    "max-iter", "deadline", "backend", "profile", "steps", "samples", "listen",
    "heartbeat", "kill-rank", "kill-after-folds",
];

/// `bsf sweep <problem> --runs N`: expand the seed grid into N
/// independent job contracts and race them over one fleet — a remote
/// one (`--control`, via [`HttpControl`]) or an embedded one spun up
/// for the sweep. Each finished run streams one `bsf-sweep/1` JSONL
/// `run` row (to `--out FILE`, else stdout) in completion order; the
/// final `summary` row aggregates, and individual run failures never
/// abort the sweep.
fn cmd_sweep_batch(args: &ArgMap) -> Result<(), BsfError> {
    use std::io::Write as _;
    args.ensure_known(SWEEP_BATCH_OPTS)?;
    let name = args
        .positional(0)
        .ok_or_else(|| BsfError::usage("sweep --runs requires a problem name"))?;
    let workers_per_run = match args.get("workers-per-run") {
        None | Some("auto") => 0,
        Some(v) => {
            let k: usize = v.parse().map_err(|_| {
                BsfError::usage(format!(
                    "--workers-per-run expects an integer or \"auto\", got {v:?}"
                ))
            })?;
            if k == 0 {
                return Err(BsfError::usage(
                    "--workers-per-run must be >= 1 (use \"auto\" for the \
                     cost-model K)",
                ));
            }
            k
        }
    };
    let timeout = match args.get("timeout") {
        None => None,
        Some(_) => {
            let secs = args.f64_or("timeout", 0.0)?;
            match Duration::try_from_secs_f64(secs) {
                Ok(d) if secs > 0.0 => Some(d),
                _ => {
                    return Err(BsfError::usage(format!(
                        "--timeout expects a finite positive number of seconds, \
                         got {secs}"
                    )))
                }
            }
        }
    };
    let spec = SweepSpec {
        problem: name.to_string(),
        runs: args.usize_or("runs", 1)?,
        seed_start: args.u64_or("seed-start", 1)?,
        seed_stride: args.u64_or("seed-stride", 1)?,
        workers_per_run,
        max_iter: match args.get("max-iter") {
            None => None,
            Some(_) => Some(args.usize_or("max-iter", 0)?),
        },
        timeout,
    };

    let mut sink: Box<dyn std::io::Write> = match args.get("out") {
        Some(path) => {
            let f = std::fs::File::create(path).map_err(|e| BsfError::Io {
                path: std::path::PathBuf::from(path),
                source: e,
            })?;
            Box::new(std::io::BufWriter::new(f))
        }
        None => Box::new(std::io::stdout()),
    };
    // `emit` can't return an error through run_sweep's FnMut surface, so
    // the first write failure is parked here and re-raised after.
    let mut io_err: Option<std::io::Error> = None;
    let summary = {
        let mut emit = |rec: &bsf::sweep::RunRecord| {
            if io_err.is_some() {
                return;
            }
            if let Err(e) = writeln!(sink, "{}", rec.to_json().compact()) {
                io_err = Some(e);
            }
        };
        if let Some(addr) = args.get("control") {
            let api = HttpControl::new(addr);
            run_sweep(&api, &spec, &mut emit)?
        } else {
            let c = common_from(args)?;
            if c.cfg.workers == 0 {
                return Err(BsfError::usage("sweep needs at least one worker"));
            }
            match name {
                "jacobi" => {
                    sweep_embedded(mk_jacobi(&c), args, name, &c, &spec, &mut emit, |x| {
                        head(x)
                    })?
                }
                "jacobi-map" => sweep_embedded(
                    mk_jacobi_map(&c),
                    args,
                    name,
                    &c,
                    &spec,
                    &mut emit,
                    |x| head(x),
                )?,
                "cimmino" => {
                    sweep_embedded(mk_cimmino(&c), args, name, &c, &spec, &mut emit, |x| {
                        head(x)
                    })?
                }
                "gravity" => {
                    sweep_embedded(mk_gravity(&c), args, name, &c, &spec, &mut emit, |x| {
                        head(x)
                    })?
                }
                "montecarlo" => sweep_embedded(
                    mk_montecarlo(&c),
                    args,
                    name,
                    &c,
                    &spec,
                    &mut emit,
                    describe_montecarlo,
                )?,
                "pagerank" => sweep_embedded(
                    mk_pagerank(&c),
                    args,
                    name,
                    &c,
                    &spec,
                    &mut emit,
                    |x| describe_pagerank(x),
                )?,
                "kmeans" => {
                    let probe = mk_kmeans(&c);
                    sweep_embedded(
                        mk_kmeans(&c),
                        args,
                        name,
                        &c,
                        &spec,
                        &mut emit,
                        move |x| format!("inertia {:.6}; {}", probe.inertia(x), head(x)),
                    )?
                }
                "sgd" => {
                    let probe = mk_sgd(&c);
                    sweep_embedded(
                        mk_sgd(&c),
                        args,
                        name,
                        &c,
                        &spec,
                        &mut emit,
                        move |p| format!("loss {:.6}; w = {}", probe.loss(p), head(&p.1)),
                    )?
                }
                "lpp" => {
                    sweep_embedded(mk_lpp(&c), args, name, &c, &spec, &mut emit, |x| {
                        head(x)
                    })?
                }
                "apex" => sweep_embedded(
                    mk_apex(&c),
                    args,
                    name,
                    &c,
                    &spec,
                    &mut emit,
                    |(x, _)| head(x),
                )?,
                other => {
                    return Err(BsfError::usage(format!(
                        "unknown problem {other:?} (sweep)"
                    )))
                }
            }
        }
    };
    if let Err(e) =
        writeln!(sink, "{}", summary.to_json().compact()).and_then(|()| sink.flush())
    {
        io_err = Some(e);
    }
    if let Some(e) = io_err {
        return Err(BsfError::Io {
            path: std::path::PathBuf::from(args.str_or("out", "stdout")),
            source: e,
        });
    }
    if let Some(path) = args.get("out") {
        eprintln!("wrote {path}");
    }
    println!("done: {}", summary.digest());
    Ok(())
}

/// The embedded half of `bsf sweep --runs`: spin up the same
/// fleet + scheduler `bsf serve` would (minus the HTTP control server —
/// the driver talks to the scheduler in-process through the same
/// `ControlApi` trait), run the sweep, tear the fleet down.
fn sweep_embedded<P: BsfProblem>(
    p: P,
    args: &ArgMap,
    name: &str,
    c: &Common,
    spec: &SweepSpec,
    emit: &mut dyn FnMut(&bsf::sweep::RunRecord),
    describe: impl Fn(&P::Param) -> String + Send + Sync + 'static,
) -> Result<bsf::sweep::SweepSummary, BsfError> {
    // Calibrate first so `--workers-per-run auto` resolves to the cost
    // model's scalability-boundary K, exactly as under `bsf serve`.
    let cal = calibrate(&p, profile_from(args)?, 3);
    let sink = Arc::new(RunTelemetry::new());
    sink.run_start("cluster", c.cfg.workers);
    sink.set_cost_model(&cal.params, c.cfg.workers.max(1));

    let cluster_spec = match args.get("listen") {
        Some(addr) => Cluster::connect(c.cfg.workers, addr),
        None => Cluster::spawn(c.cfg.workers, worker_args(name, c, args)),
    };
    let cluster = cluster_spec.start(&p)?;
    let sched = Arc::new(
        Scheduler::new(cluster.pool(), Arc::new(p), name, c.cfg.clone())
            .describe_with(describe)
            .cost_model(cal.params)
            .telemetry(sink),
    );
    eprintln!(
        "sweep: embedded {name} fleet of {} worker(s), {} run(s)",
        c.cfg.workers, spec.runs
    );
    let summary = run_sweep(&sched, spec, emit);
    // run_sweep only returns once every submitted job is terminal, so
    // the fleet is idle here whichever way the sweep went.
    cluster.shutdown()?;
    summary
}

fn cmd_predict(args: &ArgMap) -> Result<(), BsfError> {
    args.ensure_known(&["n", "seed", "profile", "samples", "steps"])?;
    let n = args.usize_or("n", 512)?;
    let seed = args.u64_or("seed", 7)?;
    let profile = profile_from(args)?;
    let samples = args.usize_or("samples", 10_000)?;
    let steps = args.usize_or("steps", 10)?;
    let name = args.positional(0).unwrap_or("jacobi");

    fn predict<P: BsfProblem>(p: &P, profile: ClusterProfile) {
        let cal = calibrate(p, profile, 5);
        let m = cal.params;
        println!("latency        L = {:.3e} s", m.latency);
        println!("order transfer   = {:.3e} s ({} B)", m.t_send, cal.order_bytes);
        println!("fold transfer    = {:.3e} s ({} B)", m.t_recv, cal.fold_bytes);
        println!("t_map (1 worker) = {:.3e} s  ({:.3e} s/elem)", m.t_map, cal.t_map_per_elem);
        println!("t_op  (master ⊕) = {:.3e} s", m.t_op);
        println!("t_proc           = {:.3e} s", m.t_proc);
        println!("T(1)             = {:.3e} s", m.iteration_time(1));
        println!("K_max (analytic) = {:.1}", m.k_max());
        println!("K_max (argmax)   = {}", m.k_max_argmax(16384));
        println!("a(K_max)         = {:.1}", m.speedup(m.k_max_argmax(16384)));
    }
    match name {
        "jacobi" => predict(&JacobiProblem::random(n, 1e-30, seed).0, profile),
        "jacobi-map" => predict(&JacobiMapProblem::random(n, 1e-30, seed).0, profile),
        "cimmino" => predict(&CimminoProblem::random(n, n, 1e-30, seed).0, profile),
        "gravity" => predict(&GravityProblem::random(n, 1e-3, steps, seed), profile),
        "montecarlo" => predict(&MonteCarloProblem::new(n, samples, 1e-12), profile),
        "lpp" => predict(&LppProblem::random(4 * n, n, seed), profile),
        other => {
            return Err(BsfError::usage(format!("unknown problem {other:?} (predict)")))
        }
    }
    Ok(())
}

/// `bsf bench`: run the fixed problem × engine × (K, T) sweep, write
/// the machine-readable `BENCH_*.json`, optionally gate against a
/// committed baseline (the CI `bench-regression` job's core).
fn cmd_bench(args: &ArgMap) -> Result<(), BsfError> {
    args.ensure_known(&["quick", "full", "label", "out", "baseline", "tolerance", "promote"])?;
    let mode = match (args.flag("quick"), args.flag("full")) {
        (true, true) => {
            return Err(BsfError::usage("--quick and --full are mutually exclusive"))
        }
        (_, true) => "full",
        _ => "quick",
    };
    let label = args.str_or("label", "pr");
    let tolerance = args.f64_or("tolerance", 0.25)?;
    if !(0.0..1.0).contains(&tolerance) {
        return Err(BsfError::usage(format!(
            "--tolerance expects a fraction in [0, 1), got {tolerance}"
        )));
    }

    eprintln!("bsf bench: running the {mode} sweep ...");
    let suite = bench_harness::run_suite(label, mode, None)?;
    for r in &suite.records {
        println!(
            "bench {:<26} iterations={:<6} wall={:.6}s msgs={} bytes={}",
            r.case.key(),
            r.iterations,
            r.wall_seconds,
            r.messages,
            r.bytes
        );
    }

    if let Some(out) = args.get("out") {
        std::fs::write(out, suite.to_json()).map_err(|e| BsfError::Io {
            path: std::path::PathBuf::from(out),
            source: e,
        })?;
        println!("wrote {out}");
    }

    if let Some(baseline_path) = args.get("baseline") {
        let text = std::fs::read_to_string(baseline_path).map_err(|e| BsfError::Io {
            path: std::path::PathBuf::from(baseline_path),
            source: e,
        })?;
        let baseline = bench_harness::BenchSuite::parse(&text)?;
        let report = bench_harness::compare(&baseline, &suite, tolerance)?;
        print!("{report}");
    }

    // --promote runs last, so a failed --baseline gate (Err above) can
    // never overwrite the baseline with a regressed sweep.
    if let Some(promote_to) = args.get("promote") {
        // Bare `--promote` parses as "true": write over the --baseline
        // path (default BENCH_baseline.json); `--promote FILE` writes
        // the measured baseline to FILE instead.
        let path = match promote_to {
            "true" | "1" | "yes" => args.str_or("baseline", "BENCH_baseline.json"),
            explicit => explicit,
        };
        bench_harness::promote(&suite, std::path::Path::new(path))?;
        println!(
            "promoted {path}: measured baseline ({} case(s), mode {mode})",
            suite.records.len()
        );
    }
    Ok(())
}

const VERIFY_OPTS: &[&str] = &[
    "problem", "workers", "k", "n", "seed", "eps", "max-iter", "max-schedules",
    "no-faults", "mutate",
];

/// `bsf verify`: exhaustive schedule exploration of the skeleton's
/// message protocol on a small model problem (see `bsf::verify`).
fn cmd_verify(args: &ArgMap) -> Result<(), BsfError> {
    args.ensure_known(VERIFY_OPTS)?;
    let workers = if args.get("workers").is_some() {
        args.usize_or("workers", 2)?
    } else {
        args.usize_or("k", 2)?
    };
    if workers == 0 {
        return Err(BsfError::usage("verify needs at least one worker"));
    }
    let n = args.usize_or("n", 12)?;
    let seed = args.u64_or("seed", 7)?;
    // A threshold no schedule can reach before the iteration cap: every
    // schedule then runs the same depth and compares byte-for-byte.
    let eps = args.f64_or("eps", 1e-30)?;
    let mutation = match args.get("mutate") {
        None => Mutation::None,
        Some("duplicate-fold") => Mutation::DuplicateFold,
        Some(other) => {
            return Err(BsfError::usage(format!(
                "unknown --mutate {other:?} (duplicate-fold)"
            )))
        }
    };
    let vcfg = VerifyConfig {
        workers,
        max_iter: args.usize_or("max-iter", 10)?,
        max_schedules: args.usize_or("max-schedules", 20_000)?,
        faults: !args.flag("no-faults"),
        mutation,
    };
    let name = args.str_or("problem", "jacobi");
    let report = match name {
        "jacobi" => run_verify(|| JacobiProblem::random(n, eps, seed).0, &vcfg),
        "cimmino" => run_verify(|| CimminoProblem::random(n, n, eps, seed).0, &vcfg),
        // A small graph in a handful of degree-weighted blocks: the
        // variable-length sparse wire path under every schedule.
        "pagerank" => {
            run_verify(|| PageRankProblem::new(n, n.clamp(1, 4), eps, seed), &vcfg)
        }
        other => {
            return Err(BsfError::usage(format!("unknown problem {other:?} (verify)")))
        }
    };

    println!(
        "verify {name}: {} schedule(s) explored ({} fault-free, {} fault-injected){}",
        report.schedules(),
        report.base_schedules,
        report.fault_schedules,
        if report.truncated { " [truncated at --max-schedules]" } else { "" },
    );
    println!(
        "  reference: {} workers, {} iterations; split-invariant: {}",
        report.workers, report.reference_iterations, report.split_invariant,
    );
    println!(
        "  losses injected: abort={} redistribute={} restart={}",
        report.abort_losses, report.redistribute_losses, report.restart_losses,
    );
    if report.ok() {
        println!(
            "  OK: no deadlock, no misrouted tag, no orphaned message, \
             bit-identical results across all schedules"
        );
        Ok(())
    } else {
        for v in &report.violations {
            eprintln!("  violation: {v}");
        }
        Err(BsfError::verify(format!(
            "{} violation(s) across {} schedule(s)",
            report.violations.len(),
            report.schedules(),
        )))
    }
}

/// Render one `/metrics` snapshot (a parsed `bsf-metrics/1` document)
/// as the `bsf top` fleet view. Tolerant of missing fields so a newer
/// master never crashes an older viewer.
fn render_top(addr: &str, m: &Json) -> String {
    let num = |k: &str| m.get(k).and_then(Json::as_f64).unwrap_or(0.0);
    let state = if m.get("ended").and_then(Json::as_bool) == Some(true) {
        "ended"
    } else {
        "running"
    };
    let mut out = String::new();
    out.push_str(&format!("bsf top — {addr} [{state}]\n"));
    out.push_str(&format!(
        "engine={} workers={} iteration={} elapsed={:.3}s losses={} rejoins={} \
         generation={}\n",
        m.get("engine").and_then(Json::as_str).unwrap_or("?"),
        num("workers") as u64,
        num("iteration") as u64,
        num("elapsed_seconds"),
        num("losses") as u64,
        num("rejoins") as u64,
        num("generation") as u64,
    ));

    out.push_str("\nphase            measured(s)  predicted(s)  meas/pred\n");
    let phases = m.get("phases");
    for name in ["send_order", "gather", "master_reduce", "process"] {
        let cell = |section: &str| {
            phases
                .and_then(|p| p.get(section))
                .and_then(|sec| sec.get(name))
                .and_then(Json::as_f64)
        };
        let measured = cell("measured").unwrap_or(0.0);
        match (cell("predicted"), cell("measured_over_predicted")) {
            (Some(pred), Some(ratio)) => out.push_str(&format!(
                "{name:<16}{measured:>12.6}{pred:>14.6}{ratio:>11.2}\n"
            )),
            _ => out.push_str(&format!(
                "{name:<16}{measured:>12.6}{:>14}{:>11}\n",
                "-", "-"
            )),
        }
    }

    out.push_str("\ntraffic:");
    for tag in ["order", "fold", "exit", "abort", "user"] {
        let t = |field: &str| {
            m.get("traffic")
                .and_then(|v| v.get(tag))
                .and_then(|v| v.get(field))
                .and_then(Json::as_u64)
                .unwrap_or(0)
        };
        out.push_str(&format!(" {tag}={}msg/{}B", t("messages"), t("bytes")));
    }
    out.push('\n');

    match m.get("workers_health").and_then(Json::as_arr) {
        Some(rows) if !rows.is_empty() => {
            out.push_str(
                "\nrank  beats  iters  map(s)      sublist  threads  reassign  pid\n",
            );
            for w in rows {
                let g = |k: &str| w.get(k).and_then(Json::as_f64).unwrap_or(0.0);
                out.push_str(&format!(
                    "{:<6}{:<7}{:<7}{:<12.6}{:<9}{:<9}{:<10}{}\n",
                    g("rank") as u64,
                    g("heartbeats") as u64,
                    g("iterations") as u64,
                    g("map_seconds"),
                    g("sublist_length") as u64,
                    g("threads") as u64,
                    g("reassignments") as u64,
                    g("pid") as u64,
                ));
            }
        }
        _ => out.push_str("\n(no worker heartbeats yet — run with --heartbeat N)\n"),
    }

    out.push_str(&format!(
        "\nevents: total={} dropped={}\n",
        num("events_total") as u64,
        num("events_dropped") as u64,
    ));
    out
}

/// `bsf top <addr>`: poll a running master's `/metrics` endpoint and
/// render a live fleet view — iteration progress, measured vs predicted
/// phase seconds, per-tag traffic, and per-worker health from
/// heartbeats.
fn cmd_top(args: &ArgMap) -> Result<(), BsfError> {
    args.ensure_known(&["interval", "once"])?;
    let addr = args
        .positional(0)
        .ok_or_else(|| {
            BsfError::usage(
                "top requires the master's metrics address (host:port) — \
                 printed by `bsf run --metrics-addr` at startup",
            )
        })?
        .to_string();
    let interval = args.f64_or("interval", 1.0)?;
    if !interval.is_finite() || interval <= 0.0 || interval > 3600.0 {
        return Err(BsfError::usage(format!(
            "--interval expects seconds in (0, 3600], got {interval}"
        )));
    }
    let once = args.flag("once");
    let timeout = Duration::from_secs(5);
    let mut connected = false;
    loop {
        match http_get(&addr, "/metrics", timeout) {
            Ok(body) => {
                let doc = Json::parse(&body).map_err(|e| {
                    BsfError::transport(format!("bad /metrics JSON from {addr}: {e}"))
                })?;
                let view = render_top(&addr, &doc);
                if once {
                    print!("{view}");
                    return Ok(());
                }
                // Clear + home, then repaint (top-style refresh).
                print!("\x1b[2J\x1b[H{view}");
                use std::io::Write as _;
                let _ = std::io::stdout().flush();
                connected = true;
                if doc.get("ended").and_then(Json::as_bool) == Some(true) {
                    eprintln!("bsf top: run ended");
                    return Ok(());
                }
            }
            // The endpoint went away after we saw it: the run is over
            // and the master exited — a clean end, not an error.
            Err(e) if connected => {
                eprintln!("bsf top: master gone ({e})");
                return Ok(());
            }
            Err(e) => return Err(e),
        }
        std::thread::sleep(Duration::from_secs_f64(interval));
    }
}

fn cmd_artifacts() -> Result<(), BsfError> {
    let rt = XlaRuntime::open_default()?;
    println!(
        "{} artifacts (PJRT backend {}):",
        rt.names().len(),
        if XlaRuntime::backend_available() { "linked" } else { "not linked" }
    );
    for name in rt.names() {
        if let Some(m) = rt.meta(name) {
            println!("  {name}  kind={} n={} c={} out={:?}", m.kind, m.n, m.c, m.out_dims);
        }
    }
    Ok(())
}

fn dispatch(args: &ArgMap) -> Result<(), BsfError> {
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(args, engine_from(args)?),
        Some("worker") => cmd_worker(args),
        Some("sim") => {
            if args.get("engine").is_some() {
                return Err(BsfError::usage(
                    "--engine conflicts with the sim subcommand (sim always \
                     uses the simulated engine; use `run --engine ...` instead)",
                ));
            }
            cmd_run(args, EngineOpt::Simulated(profile_from(args)?))
        }
        Some("sweep") => cmd_sweep(args),
        Some("predict") => cmd_predict(args),
        Some("bench") => cmd_bench(args),
        Some("verify") => cmd_verify(args),
        Some("serve") => cmd_serve(args),
        Some("submit") => cmd_submit(args),
        Some("jobs") => cmd_jobs(args),
        Some("shutdown") => cmd_shutdown(args),
        Some("top") => cmd_top(args),
        Some("artifacts") => cmd_artifacts(),
        Some("help") | None => {
            println!("{USAGE}");
            Ok(())
        }
        Some(other) => Err(BsfError::usage(format!("unknown subcommand {other:?}"))),
    }
}

fn main() {
    let args = ArgMap::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("bsf: {e}");
        if matches!(e, BsfError::Usage(_)) {
            eprintln!("\n{USAGE}");
        }
        std::process::exit(e.exit_code());
    }
}
