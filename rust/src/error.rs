//! Typed errors for the whole skeleton (`BsfError`).
//!
//! The seed port failed by `panic!`/`expect` everywhere; every public
//! entry point now returns `Result<_, BsfError>` instead, so embedders
//! can react to a mis-configured run, a torn transport or a missing AOT
//! artifact without aborting the process. The enum is written in the
//! `thiserror` style by hand — the offline dependency universe has no
//! proc-macro crates (see Cargo.toml).

use std::fmt;
use std::path::PathBuf;

/// Everything that can go wrong inside the BSF skeleton.
#[derive(Debug)]
pub enum BsfError {
    /// Invalid run configuration or problem wiring (zero workers, a
    /// `job_count` outside `1..=MAX_JOBS`, an empty map-list, a
    /// `next_job` out of range, ...).
    Config(String),
    /// The message-passing substrate failed (endpoint hung up, rank out
    /// of range, poisoned inbox).
    Transport(String),
    /// A specific worker became unreachable mid-run (its process died,
    /// its connection tore, or a fault was injected). Unlike the generic
    /// [`Transport`](Self::Transport) case the lost rank is known, which
    /// is what lets a [`FaultPolicy`](crate::skeleton::fault::FaultPolicy)
    /// re-plan the run on the survivors instead of aborting.
    WorkerLost {
        /// Rank of the unreachable worker.
        rank: usize,
        /// Human-readable cause (EOF, broken pipe, injected fault, ...).
        reason: String,
    },
    /// A worker thread panicked inside user map/reduce code.
    WorkerPanic {
        /// Rank of the worker whose thread died.
        rank: usize,
    },
    /// The run was aborted between iterations by its
    /// [`CancelToken`](crate::skeleton::driver::CancelToken). Workers
    /// were released (exit broadcast) before this error surfaced.
    Cancelled,
    /// The persistent cluster has no free capacity for this launch:
    /// other jobs hold its workers (or a one-shot `Cluster::engine()`
    /// run is active). Queue the work through a scheduler (`bsf serve`
    /// + `bsf submit`) instead of racing for the whole fleet.
    ClusterBusy {
        /// Number of jobs currently holding leases on the fleet.
        active_jobs: usize,
    },
    /// Artifact registry problems: malformed `manifest.tsv`, unknown
    /// artifact name, output-shape mismatch.
    Artifact(String),
    /// A PJRT/XLA operation failed (compile, execute, reshape).
    Xla(String),
    /// No PJRT backend is linked into this build (see `runtime::pjrt`).
    XlaUnavailable(String),
    /// Filesystem error while reading artifacts.
    Io {
        path: PathBuf,
        source: std::io::Error,
    },
    /// CLI usage error (unknown subcommand/option, unparsable value).
    Usage(String),
    /// Bench harness failure: a malformed `BENCH_*.json`, a missing
    /// case in a comparison, or a regression outside tolerance (the CI
    /// `bench-regression` gate).
    Bench(String),
    /// The model checker (`bsf verify`) found protocol violations —
    /// deadlocks, misrouted tags, orphaned messages or
    /// schedule-dependent results.
    Verify(String),
}

impl BsfError {
    /// Shorthand constructors keep call sites one line long.
    pub fn config(msg: impl Into<String>) -> Self {
        BsfError::Config(msg.into())
    }

    /// Shorthand for [`BsfError::Transport`].
    pub fn transport(msg: impl Into<String>) -> Self {
        BsfError::Transport(msg.into())
    }

    /// A transport failure caused by an I/O error (socket refused, torn
    /// connection, failed spawn): keeps the OS error text in context.
    pub fn transport_io(context: impl Into<String>, source: std::io::Error) -> Self {
        BsfError::Transport(format!("{}: {source}", context.into()))
    }

    /// A specific worker became unreachable mid-run.
    pub fn worker_lost(rank: usize, reason: impl Into<String>) -> Self {
        BsfError::WorkerLost { rank, reason: reason.into() }
    }

    /// Shorthand for [`BsfError::Artifact`].
    pub fn artifact(msg: impl Into<String>) -> Self {
        BsfError::Artifact(msg.into())
    }

    /// Shorthand for [`BsfError::Xla`].
    pub fn xla(msg: impl Into<String>) -> Self {
        BsfError::Xla(msg.into())
    }

    /// Shorthand for [`BsfError::Usage`].
    pub fn usage(msg: impl Into<String>) -> Self {
        BsfError::Usage(msg.into())
    }

    /// Shorthand for [`BsfError::Bench`].
    pub fn bench(msg: impl Into<String>) -> Self {
        BsfError::Bench(msg.into())
    }

    /// Shorthand for [`BsfError::Verify`].
    pub fn verify(msg: impl Into<String>) -> Self {
        BsfError::Verify(msg.into())
    }

    /// Conventional process exit code for this error (CLI use).
    pub fn exit_code(&self) -> i32 {
        match self {
            BsfError::Usage(_) => 2,
            _ => 1,
        }
    }
}

impl fmt::Display for BsfError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BsfError::Config(msg) => write!(f, "configuration error: {msg}"),
            BsfError::Transport(msg) => write!(f, "transport error: {msg}"),
            BsfError::WorkerLost { rank, reason } => {
                write!(f, "worker {rank} lost mid-run: {reason}")
            }
            BsfError::WorkerPanic { rank } => {
                write!(f, "worker {rank} panicked in user map/reduce code")
            }
            BsfError::Cancelled => {
                write!(f, "run cancelled between iterations (workers released)")
            }
            BsfError::ClusterBusy { active_jobs } => {
                write!(
                    f,
                    "cluster busy: {active_jobs} active job(s) hold its workers \
                     — submit through a scheduler (`bsf serve` + `bsf submit`) \
                     instead of racing for the fleet"
                )
            }
            BsfError::Artifact(msg) => write!(f, "artifact error: {msg}"),
            BsfError::Xla(msg) => write!(f, "xla error: {msg}"),
            BsfError::XlaUnavailable(msg) => write!(f, "xla unavailable: {msg}"),
            BsfError::Io { path, source } => {
                write!(f, "io error at {}: {source}", path.display())
            }
            BsfError::Usage(msg) => write!(f, "usage error: {msg}"),
            BsfError::Bench(msg) => write!(f, "bench error: {msg}"),
            BsfError::Verify(msg) => write!(f, "verification failed: {msg}"),
        }
    }
}

impl std::error::Error for BsfError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            BsfError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

/// Crate-wide result alias.
pub type BsfResult<T> = std::result::Result<T, BsfError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_carries_context() {
        let e = BsfError::config("need at least one worker");
        assert!(e.to_string().contains("configuration error"));
        assert!(e.to_string().contains("one worker"));
    }

    #[test]
    fn io_error_has_source() {
        use std::error::Error;
        let e = BsfError::Io {
            path: PathBuf::from("/nope/manifest.tsv"),
            source: std::io::Error::new(std::io::ErrorKind::NotFound, "gone"),
        };
        assert!(e.source().is_some());
        assert!(e.to_string().contains("manifest.tsv"));
    }

    #[test]
    fn transport_io_keeps_both_contexts() {
        let e = BsfError::transport_io(
            "worker 2: connect to master",
            std::io::Error::new(std::io::ErrorKind::ConnectionRefused, "refused"),
        );
        assert!(matches!(e, BsfError::Transport(_)));
        assert!(e.to_string().contains("worker 2"));
        assert!(e.to_string().contains("refused"));
    }

    #[test]
    fn usage_errors_exit_2() {
        assert_eq!(BsfError::usage("bad flag").exit_code(), 2);
        assert_eq!(BsfError::config("x").exit_code(), 1);
    }
}
