//! An HTTP-backed [`ControlApi`]: the client half of the `bsf serve`
//! control plane.
//!
//! [`HttpControl`] implements the same trait the scheduler implements
//! in-process, by speaking the control server's endpoints (`POST
//! /jobs`, `GET /jobs`, `POST /jobs/<id>/cancel`, `POST /shutdown`,
//! `GET /metrics`, `GET /events`) over std-only HTTP/1.0. That makes
//! the sweep driver — and anything else written against `ControlApi` —
//! deployment-agnostic: hand it an `Arc<Scheduler>` for an embedded
//! fleet or an `HttpControl` for a remote one.
//!
//! The trait's infallible methods (`jobs_json`, `shutdown_json`,
//! `metrics_json`, `events_jsonl`) cannot surface a transport error
//! through their signatures; on failure they return an empty document
//! carrying an `"error"` field, which callers like
//! [`run_sweep`](crate::sweep::run_sweep) detect as a malformed
//! response and turn into a typed error.

use crate::error::BsfError;
use crate::metrics::exporter::{http_get, http_post};
use crate::skeleton::ControlApi;
use crate::util::json::Json;
use std::time::Duration;

/// A remote `bsf serve` control endpoint as a [`ControlApi`].
pub struct HttpControl {
    addr: String,
    timeout: Duration,
}

impl HttpControl {
    /// Client for the control server at `addr` (`HOST:PORT`), with a
    /// per-request timeout of 10 seconds.
    pub fn new(addr: &str) -> Self {
        Self { addr: addr.to_string(), timeout: Duration::from_secs(10) }
    }

    /// Override the per-request timeout.
    pub fn timeout(mut self, t: Duration) -> Self {
        self.timeout = t;
        self
    }

    fn get_json(&self, path: &str) -> Json {
        match http_get(&self.addr, path, self.timeout)
            .and_then(|body| Json::parse(&body).map_err(BsfError::transport))
        {
            Ok(doc) => doc,
            Err(e) => Json::obj(vec![("error", Json::Str(e.to_string()))]),
        }
    }
}

impl ControlApi for HttpControl {
    fn submit_json(&self, req: &Json) -> Result<Json, BsfError> {
        let body = http_post(&self.addr, "/jobs", &req.compact(), self.timeout)?;
        Json::parse(&body).map_err(BsfError::transport)
    }

    fn jobs_json(&self) -> Json {
        self.get_json("/jobs")
    }

    fn cancel_json(&self, id: u64) -> Result<Json, BsfError> {
        let body = http_post(
            &self.addr,
            &format!("/jobs/{id}/cancel"),
            "",
            self.timeout,
        )?;
        Json::parse(&body).map_err(BsfError::transport)
    }

    fn shutdown_json(&self) -> Json {
        match http_post(&self.addr, "/shutdown", "", self.timeout)
            .and_then(|body| Json::parse(&body).map_err(BsfError::transport))
        {
            Ok(doc) => doc,
            Err(e) => Json::obj(vec![("error", Json::Str(e.to_string()))]),
        }
    }

    fn metrics_json(&self) -> Json {
        self.get_json("/metrics")
    }

    fn events_jsonl(&self) -> String {
        http_get(&self.addr, "/events", self.timeout).unwrap_or_default()
    }
}
