//! Batch-sweep engine: N independent seeded runs over one fleet.
//!
//! `bsf sweep <problem> --runs N --seed-start S --seed-stride D` is the
//! embarrassingly-parallel, high-job-count regime the paper's cost
//! model covers but single long-running jobs never exercise: the seed
//! grid `S, S+D, S+2D, ...` expands into N independent
//! [`JobContract`](crate::skeleton::JobContract)s (each with
//! [`JobContract::seed`](crate::skeleton::JobContract::seed) set),
//! submitted through the ordinary scheduler admission path and raced
//! across whatever worker leases the fleet can grant.
//!
//! The driver, [`run_sweep`], is written against the [`ControlApi`]
//! *JSON* surface — the same trait object the HTTP control server
//! wraps — so one implementation serves both deployment shapes:
//!
//! * **embedded** — `bsf sweep` with no `--control` spawns its own
//!   fleet and scheduler in-process and hands the driver the
//!   `Arc<Scheduler>` directly;
//! * **remote** — `--control HOST:PORT` hands it an [`HttpControl`],
//!   which speaks the `POST /jobs` / `GET /jobs` endpoints of a running
//!   `bsf serve`.
//!
//! Results stream as schema-versioned JSONL (`bsf-sweep/1`), one `run`
//! record per finished run **in completion order** plus one final
//! `summary` record. Individual run failures (a worker killed mid-run,
//! an admission rejection) are recorded as `"status": "failed"` rows and
//! the sweep continues — fault tolerance rides the scheduler's existing
//! `FaultPolicy::Redistribute` plumbing, whose budget for a k-worker
//! lease is k − 1 losses.
//!
//! Because each run's seed flows through
//! [`BsfProblem::seeded_parameter`](crate::skeleton::BsfProblem::seeded_parameter)
//! and the iteration-0 checkpoint path, a sweep run's `result` text is
//! byte-identical to a solo `bsf run <problem> --run-seed SEED` of the
//! same seed — the CI sweep-smoke job byte-compares exactly that.

mod http;

pub use http::HttpControl;

use crate::error::BsfError;
use crate::skeleton::ControlApi;
use crate::util::json::Json;
use std::time::{Duration, Instant};

/// Wire-schema tag stamped on every JSONL record the sweep emits.
pub const SWEEP_SCHEMA: &str = "bsf-sweep/1";

/// How often the driver polls `GET /jobs` for completions.
const POLL_INTERVAL: Duration = Duration::from_millis(50);

/// What to sweep: the seed grid and the per-run contract knobs.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    /// Problem name (must match what the fleet serves).
    pub problem: String,
    /// Number of independent runs.
    pub runs: usize,
    /// Seed of run 0.
    pub seed_start: u64,
    /// Seed increment between consecutive runs (wrapping).
    pub seed_stride: u64,
    /// Workers per run; `0` = auto (the scheduler's cost-model K).
    pub workers_per_run: usize,
    /// Optional per-run iteration cap.
    pub max_iter: Option<usize>,
    /// Optional whole-sweep wall-clock budget: on expiry the driver
    /// cancels outstanding jobs and records them as failed.
    pub timeout: Option<Duration>,
}

impl SweepSpec {
    /// Seed of the i-th run: `seed_start + i * seed_stride` (wrapping).
    pub fn seed_of(&self, run: usize) -> u64 {
        self.seed_start
            .wrapping_add(self.seed_stride.wrapping_mul(run as u64))
    }

    /// The `POST /jobs` body for the i-th run.
    pub fn submit_body(&self, run: usize) -> Json {
        let mut fields = vec![
            ("problem", Json::Str(self.problem.clone())),
            ("seed", Json::Num(self.seed_of(run) as f64)),
        ];
        if self.workers_per_run > 0 {
            fields.push(("workers", Json::Num(self.workers_per_run as f64)));
        } else {
            fields.push(("workers", Json::Str("auto".into())));
        }
        if let Some(n) = self.max_iter {
            fields.push(("max_iter", Json::Num(n as f64)));
        }
        Json::obj(fields)
    }
}

/// One finished (or failed) run of the sweep.
#[derive(Debug, Clone)]
pub struct RunRecord {
    /// Run index in the seed grid (0-based).
    pub run: usize,
    /// The seed this run started from.
    pub seed: u64,
    /// Scheduler job id (`None` when the submission itself failed).
    pub job: Option<u64>,
    /// Terminal status: `done`, `failed` or `cancelled`.
    pub status: String,
    /// Workers actually granted to the run.
    pub workers: usize,
    /// Iterations completed.
    pub iterations: usize,
    /// Run wall seconds (queue wait excluded).
    pub elapsed: f64,
    /// The rendered `result:` line text (byte-identical to the solo
    /// `bsf run --run-seed` of the same seed), when the run succeeded.
    pub result: Option<String>,
    /// Error text for failed runs.
    pub error: Option<String>,
}

impl RunRecord {
    /// One `bsf-sweep/1` JSONL `run` row.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::Str(SWEEP_SCHEMA.into())),
            ("kind", Json::Str("run".into())),
            ("run", Json::Num(self.run as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("job", self.job.map_or(Json::Null, |id| Json::Num(id as f64))),
            ("status", Json::Str(self.status.clone())),
            ("workers", Json::Num(self.workers as f64)),
            ("iterations", Json::Num(self.iterations as f64)),
            ("elapsed", Json::Num(self.elapsed)),
            ("result", self.result.clone().map_or(Json::Null, Json::Str)),
            ("error", self.error.clone().map_or(Json::Null, Json::Str)),
        ])
    }
}

/// Aggregate statistics over the whole sweep.
#[derive(Debug, Clone)]
pub struct SweepSummary {
    /// Problem swept.
    pub problem: String,
    /// Runs requested.
    pub runs: usize,
    /// Runs that finished `done`.
    pub done: usize,
    /// Runs that ended `failed` (including failed submissions).
    pub failed: usize,
    /// Runs that ended `cancelled` (sweep timeout).
    pub cancelled: usize,
    /// Total iterations across successful runs.
    pub total_iterations: usize,
    /// Shortest successful run (seconds); 0 when none succeeded.
    pub min_run_seconds: f64,
    /// Longest successful run (seconds).
    pub max_run_seconds: f64,
    /// Mean successful-run seconds.
    pub mean_run_seconds: f64,
    /// Whole-sweep wall seconds (submission to last completion).
    pub wall_seconds: f64,
}

impl SweepSummary {
    /// The final `bsf-sweep/1` JSONL `summary` row.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::Str(SWEEP_SCHEMA.into())),
            ("kind", Json::Str("summary".into())),
            ("problem", Json::Str(self.problem.clone())),
            ("runs", Json::Num(self.runs as f64)),
            ("done", Json::Num(self.done as f64)),
            ("failed", Json::Num(self.failed as f64)),
            ("cancelled", Json::Num(self.cancelled as f64)),
            ("total_iterations", Json::Num(self.total_iterations as f64)),
            ("min_run_seconds", Json::Num(self.min_run_seconds)),
            ("max_run_seconds", Json::Num(self.max_run_seconds)),
            ("mean_run_seconds", Json::Num(self.mean_run_seconds)),
            ("wall_seconds", Json::Num(self.wall_seconds)),
        ])
    }

    /// The one-line human digest `bsf sweep` prints after `done:`.
    pub fn digest(&self) -> String {
        format!(
            "swept {} × {}: {} done, {} failed, {} cancelled in {:.3}s",
            self.runs, self.problem, self.done, self.failed, self.cancelled,
            self.wall_seconds
        )
    }
}

/// A run the driver is still waiting on.
struct Pending {
    run: usize,
    seed: u64,
    job: u64,
}

/// Expand the seed grid, submit every run, and stream completions.
///
/// `emit` is called once per run **in completion order** (failed
/// submissions first, then jobs as they reach a terminal status) and
/// the aggregated summary is returned. The driver itself never aborts
/// on a run failure — only on control-plane breakdown (the endpoint
/// stops answering, or a full poll pass yields undecodable rows).
pub fn run_sweep(
    api: &dyn ControlApi,
    spec: &SweepSpec,
    emit: &mut dyn FnMut(&RunRecord),
) -> Result<SweepSummary, BsfError> {
    if spec.runs == 0 {
        return Err(BsfError::usage("sweep: --runs must be >= 1"));
    }
    let started = Instant::now();
    let mut records: Vec<RunRecord> = Vec::with_capacity(spec.runs);
    let mut pending: Vec<Pending> = Vec::with_capacity(spec.runs);

    for run in 0..spec.runs {
        let seed = spec.seed_of(run);
        match api.submit_json(&spec.submit_body(run)) {
            Ok(resp) => {
                let job = resp.get("id").and_then(Json::as_u64).ok_or_else(|| {
                    BsfError::transport(format!(
                        "sweep: submit response without an id: {}",
                        resp.compact()
                    ))
                })?;
                pending.push(Pending { run, seed, job });
            }
            Err(e) => {
                // The fleet refused this run (admission shrank, bad
                // contract); record it and keep sweeping the rest.
                let rec = RunRecord {
                    run,
                    seed,
                    job: None,
                    status: "failed".into(),
                    workers: 0,
                    iterations: 0,
                    elapsed: 0.0,
                    result: None,
                    error: Some(e.to_string()),
                };
                emit(&rec);
                records.push(rec);
            }
        }
    }

    let mut timed_out = false;
    while !pending.is_empty() {
        if let Some(budget) = spec.timeout {
            if started.elapsed() > budget && !timed_out {
                timed_out = true;
                for p in &pending {
                    let _ = api.cancel_json(p.job);
                }
            }
            if started.elapsed() > budget + Duration::from_secs(30) {
                // Cancellation itself wedged — drain what we know and
                // record the rest as failed rather than hanging forever.
                for p in pending.drain(..) {
                    let rec = RunRecord {
                        run: p.run,
                        seed: p.seed,
                        job: Some(p.job),
                        status: "failed".into(),
                        workers: 0,
                        iterations: 0,
                        elapsed: 0.0,
                        result: None,
                        error: Some("sweep timeout: job never reached a terminal status".into()),
                    };
                    emit(&rec);
                    records.push(rec);
                }
                break;
            }
        }
        let doc = api.jobs_json();
        let rows = doc.get("jobs").and_then(|j| j.as_arr()).ok_or_else(|| {
            BsfError::transport(format!(
                "sweep: malformed bsf-jobs document: {}",
                doc.compact()
            ))
        })?;
        pending.retain(|p| {
            let Some(row) = rows
                .iter()
                .find(|r| r.get("id").and_then(Json::as_u64) == Some(p.job))
            else {
                return true; // not visible yet; keep waiting
            };
            let status = row
                .get("status")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string();
            if !matches!(status.as_str(), "done" | "failed" | "cancelled") {
                return true;
            }
            let rec = RunRecord {
                run: p.run,
                seed: p.seed,
                job: Some(p.job),
                status,
                workers: row
                    .get("granted")
                    .and_then(|g| g.as_arr())
                    .map_or(0, <[Json]>::len),
                iterations: row
                    .get("iterations")
                    .and_then(Json::as_u64)
                    .unwrap_or(0) as usize,
                elapsed: row.get("elapsed").and_then(Json::as_f64).unwrap_or(0.0),
                result: row
                    .get("result")
                    .and_then(Json::as_str)
                    .map(str::to_string),
                error: row
                    .get("error")
                    .and_then(Json::as_str)
                    .map(str::to_string),
            };
            emit(&rec);
            records.push(rec);
            false
        });
        if !pending.is_empty() {
            std::thread::sleep(POLL_INTERVAL);
        }
    }

    let done: Vec<&RunRecord> =
        records.iter().filter(|r| r.status == "done").collect();
    let sum_elapsed: f64 = done.iter().map(|r| r.elapsed).sum();
    Ok(SweepSummary {
        problem: spec.problem.clone(),
        runs: spec.runs,
        done: done.len(),
        failed: records.iter().filter(|r| r.status == "failed").count(),
        cancelled: records.iter().filter(|r| r.status == "cancelled").count(),
        total_iterations: done.iter().map(|r| r.iterations).sum(),
        min_run_seconds: if done.is_empty() {
            0.0
        } else {
            done.iter().map(|r| r.elapsed).fold(f64::INFINITY, f64::min)
        },
        max_run_seconds: done.iter().map(|r| r.elapsed).fold(0.0, f64::max),
        mean_run_seconds: if done.is_empty() {
            0.0
        } else {
            sum_elapsed / done.len() as f64
        },
        wall_seconds: started.elapsed().as_secs_f64(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::montecarlo::MonteCarloProblem;
    use crate::skeleton::backend::FusedNativeBackend;
    use crate::skeleton::cluster::serve_worker;
    use crate::skeleton::config::BsfConfig;
    use crate::skeleton::driver::Checkpoint;
    use crate::skeleton::process::ChildSet;
    use crate::skeleton::session::Bsf;
    use crate::skeleton::{Scheduler, WorkerPool};
    use crate::transport::build_thread_transport;
    use std::sync::Arc;
    use std::thread;

    fn mk() -> MonteCarloProblem {
        let mut p = MonteCarloProblem::new(8, 200, 1e-9);
        p.max_rounds = 3;
        p
    }

    fn describe(t: &(u64, u64, u64)) -> String {
        format!(
            "pi ≈ {:.6} ({} samples)",
            MonteCarloProblem::estimate(t),
            t.2
        )
    }

    #[test]
    fn embedded_sweep_matches_solo_seeded_runs() {
        // In-process fleet: 2 serve_worker threads over the thread
        // transport, a scheduler on top, and the sweep driver talking
        // to it through the same ControlApi surface bsf serve exposes.
        let k = 2;
        let mut eps = build_thread_transport(k);
        let master = eps.pop().unwrap();
        let handles: Vec<_> = eps
            .into_iter()
            .map(|ep| {
                let p = mk();
                let cfg = BsfConfig::with_workers(k);
                thread::spawn(move || serve_worker(&p, &FusedNativeBackend, &ep, &cfg))
            })
            .collect();
        let pool =
            Arc::new(WorkerPool::new(Arc::new(master), ChildSet::default(), None));
        let sched = Arc::new(
            Scheduler::new(
                Arc::clone(&pool),
                Arc::new(mk()),
                "montecarlo",
                BsfConfig::with_workers(k),
            )
            .describe_with(describe),
        );
        let spec = SweepSpec {
            problem: "montecarlo".into(),
            runs: 3,
            seed_start: 5,
            seed_stride: 1,
            workers_per_run: 1,
            max_iter: None,
            timeout: None,
        };
        let mut records = Vec::new();
        let summary =
            run_sweep(&sched, &spec, &mut |r| records.push(r.clone())).unwrap();
        assert_eq!(summary.done, 3);
        assert_eq!(summary.failed + summary.cancelled, 0);
        assert_eq!(records.len(), 3);
        for rec in &records {
            assert_eq!(rec.status, "done");
            assert_eq!(rec.iterations, 3);
            // Byte-compare against the solo seeded run of the same seed
            // — the sweep acceptance invariant.
            let solo = Bsf::new(mk())
                .workers(1)
                .resume(Checkpoint {
                    param: mk().seeded_parameter(rec.seed),
                    iter: 0,
                    job: 0,
                })
                .run()
                .unwrap();
            assert_eq!(
                rec.result.as_deref(),
                Some(describe(&solo.param).as_str()),
                "seed {} diverged between sweep and solo",
                rec.seed
            );
        }
        // Distinct seeds drew distinct streams.
        assert_ne!(records[0].result, records[1].result);
        pool.shutdown().unwrap();
        for h in handles {
            h.join().unwrap().unwrap();
        }
    }

    #[test]
    fn seed_grid_expands_with_stride() {
        let spec = SweepSpec {
            problem: "montecarlo".into(),
            runs: 4,
            seed_start: 100,
            seed_stride: 10,
            workers_per_run: 0,
            max_iter: None,
            timeout: None,
        };
        assert_eq!(
            (0..4).map(|i| spec.seed_of(i)).collect::<Vec<_>>(),
            vec![100, 110, 120, 130]
        );
        let body = spec.submit_body(2);
        assert_eq!(body.get("seed").and_then(Json::as_u64), Some(120));
        assert_eq!(body.get("workers").and_then(Json::as_str), Some("auto"));
    }

    #[test]
    fn records_round_trip_through_the_schema() {
        let rec = RunRecord {
            run: 3,
            seed: 777,
            job: Some(12),
            status: "done".into(),
            workers: 2,
            iterations: 40,
            elapsed: 0.25,
            result: Some("x = 1".into()),
            error: None,
        };
        let j = rec.to_json();
        assert_eq!(j.get("schema").and_then(Json::as_str), Some(SWEEP_SCHEMA));
        assert_eq!(j.get("kind").and_then(Json::as_str), Some("run"));
        assert_eq!(j.get("seed").and_then(Json::as_u64), Some(777));
        let reparsed = Json::parse(&j.compact()).unwrap();
        assert_eq!(reparsed.get("result").and_then(Json::as_str), Some("x = 1"));
    }
}
