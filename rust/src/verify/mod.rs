//! `bsf verify` — bounded model checking of the skeleton's message
//! protocol (the star topology of Algorithm 2, plus the fault-recovery
//! extensions) by exhaustive schedule exploration.
//!
//! The real master and worker state machines run unmodified over a
//! scheduler-controlled transport ([`vcomm`]); every bounded
//! message-delivery interleaving of a small problem is enumerated
//! ([`explore`]) and checked against the protocol invariants:
//!
//! 1. **No deadlock, no hang** — every schedule completes.
//! 2. **No misrouted tag** — every delivered message's tag is delivered
//!    to the role the [`transport::tags`](crate::transport::tags)
//!    registry declares as its receiver.
//! 3. **No orphan** — at run end no message is left undelivered or
//!    undrained at a live rank (the invariant whose violation was the
//!    PR 5 duplicate-fold bug).
//! 4. **Schedule determinism** — the final approximation is
//!    byte-identical across all schedules (the paper's claim that the
//!    skeleton's gather order never changes the result).
//! 5. **Fault equivalence** — with a worker killed at every injection
//!    point under each [`FaultPolicy`]: `Redistribute` completes on the
//!    survivors with the same bytes (split-invariant problems),
//!    `RestartFromCheckpoint` resumes to bit-identical bytes, and
//!    `Abort` fails typed, naming the victim, with every survivor
//!    released.
//!
//! Teeth: [`Mutation::DuplicateFold`] seeds the PR 5 bug (a worker
//! double-sends a fold) into an otherwise healthy world — `run_verify`
//! must then report violations, which `rust/tests/verify.rs` asserts.

pub mod explore;
pub mod vcomm;

use crate::error::BsfError;
use crate::skeleton::config::BsfConfig;
use crate::skeleton::fault::FaultPolicy;
use crate::skeleton::problem::BsfProblem;

use explore::{run_schedule, Dfs, ScheduleResult};
use vcomm::{FaultPlan, SchedOutcome};

/// Keep the violation list readable: after this many entries further
/// findings are counted, not printed.
const MAX_REPORTED: usize = 40;

/// Optional seeded bug, to prove the checker catches what it claims to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mutation {
    /// No seeded bug: the real protocol, expected to pass.
    None,
    /// Worker 0 sends its first fold twice (the PR 5 bug class).
    DuplicateFold,
}

/// Exploration bounds for one `run_verify`.
#[derive(Debug, Clone)]
pub struct VerifyConfig {
    /// Worker count K of the model world (keep small: the schedule
    /// space is exponential in K and the iteration count).
    pub workers: usize,
    /// Iteration cap — the model's run length (the problem should *not*
    /// converge earlier, so every schedule runs the same depth).
    pub max_iter: usize,
    /// Hard ceiling on explored schedules (exploration is truncated,
    /// and reported as such, beyond it).
    pub max_schedules: usize,
    /// Also explore fault-injection schedules under every policy.
    pub faults: bool,
    /// Seeded bug to inject (sanity check of the checker itself).
    pub mutation: Mutation,
}

impl Default for VerifyConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            max_iter: 10,
            max_schedules: 20_000,
            faults: true,
            mutation: Mutation::None,
        }
    }
}

/// What `run_verify` explored and what it found.
#[derive(Debug, Clone)]
pub struct VerifyReport {
    /// Worker count K the worlds were built with.
    pub workers: usize,
    /// Iterations of the canonical (reference) schedule.
    pub reference_iterations: usize,
    /// Fault-free schedules explored.
    pub base_schedules: usize,
    /// Fault-injection schedules explored (restart generations included).
    pub fault_schedules: usize,
    /// Losses actually injected, per policy (each must be ≥ 1 for the
    /// fault legs to have been exercised).
    pub abort_losses: usize,
    /// Losses injected under the redistribute policy.
    pub redistribute_losses: usize,
    /// Losses injected under the restart policy.
    pub restart_losses: usize,
    /// Exploration hit `max_schedules` before exhausting the tree.
    pub truncated: bool,
    /// Whether the K-worker and (K-1)-worker references agreed — only
    /// then is the stronger `Redistribute` byte-equality check enforced.
    pub split_invariant: bool,
    /// Findings beyond [`MAX_REPORTED`] are summarized in the last entry.
    pub violations: Vec<String>,
}

impl VerifyReport {
    /// Total schedules explored (base + fault).
    pub fn schedules(&self) -> usize {
        self.base_schedules + self.fault_schedules
    }

    /// True when no violations were found.
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }
}

struct Findings {
    violations: Vec<String>,
    suppressed: usize,
}

impl Findings {
    fn new() -> Self {
        Self { violations: Vec::new(), suppressed: 0 }
    }

    fn note(&mut self, msg: String) {
        if self.violations.len() < MAX_REPORTED {
            self.violations.push(msg);
        } else {
            self.suppressed += 1;
        }
    }

    fn into_violations(mut self) -> Vec<String> {
        if self.suppressed > 0 {
            self.violations.push(format!("... and {} more violation(s)", self.suppressed));
        }
        self.violations
    }
}

/// Checks shared by every schedule (fault-free or not).
fn check_common<Param>(
    f: &mut Findings,
    id: &str,
    r: &ScheduleResult<Param>,
    expect_completed: bool,
) {
    match &r.drive.outcome {
        SchedOutcome::Completed => {}
        SchedOutcome::Deadlock(why) if expect_completed => {
            f.note(format!("{id}: deadlock: {why}"));
        }
        SchedOutcome::Hang(why) if expect_completed => {
            f.note(format!("{id}: hang: {why}"));
        }
        _ => {}
    }
    for m in &r.drive.misrouted {
        f.note(format!("{id}: misrouted: {m}"));
    }
    if r.panics > 0 {
        let detail: Vec<&str> = r
            .worker_errors
            .iter()
            .filter(|(_, e)| e.contains("panicked"))
            .map(|(_, e)| e.as_str())
            .collect();
        f.note(format!("{id}: {} thread panic(s): {detail:?}", r.panics));
    }
}

/// The canonical schedule's outputs, against which every other schedule
/// is compared.
struct Reference {
    bytes: Vec<u8>,
    iters: usize,
    /// K-worker and (K-1)-worker runs agreed byte-for-byte, so the
    /// stronger `Redistribute` equality check is enforceable.
    split_invariant: bool,
}

/// A schedule of a healthy, fault-free world: everything must be clean
/// and bit-identical to the reference.
fn check_base<Param>(
    f: &mut Findings,
    id: &str,
    r: &ScheduleResult<Param>,
    rf: &Reference,
) {
    check_common(f, id, r, true);
    match &r.master {
        Ok(s) => {
            if s.param_bytes != rf.bytes {
                f.note(format!(
                    "{id}: schedule-dependent result: final parameter bytes \
                     differ from the reference schedule"
                ));
            }
            if s.iterations != rf.iters {
                f.note(format!(
                    "{id}: ran {} iterations, reference ran {}",
                    s.iterations, rf.iters
                ));
            }
        }
        Err((e, _)) => f.note(format!("{id}: master failed: {e}")),
    }
    for (rank, e) in &r.worker_errors {
        f.note(format!("{id}: worker {rank} failed: {e}"));
    }
    for l in &r.leftovers {
        f.note(format!("{id}: orphan: {l}"));
    }
}

/// One generation with a scheduled worker kill, checked per policy.
/// Returns a checkpoint when the policy is restart-from-checkpoint and
/// this generation died recoverably.
fn check_fault<Param: Clone>(
    f: &mut Findings,
    id: &str,
    policy: &FaultPolicy,
    victim: usize,
    r: &ScheduleResult<Param>,
    rf: &Reference,
) -> Option<crate::skeleton::driver::Checkpoint<Param>> {
    check_common(f, id, r, true);
    let fired = r.drive.fault_fired;
    // Survivor worker loops must stay healthy whenever the master
    // finished cleanly (on a master error, release ordering can leave a
    // survivor seeing the abort first — not a protocol violation).
    if r.master.is_ok() {
        for (rank, e) in &r.worker_errors {
            if *rank != victim {
                f.note(format!("{id}: survivor worker {rank} failed: {e}"));
            }
        }
    }
    match (&r.master, policy) {
        (Ok(s), _) if !fired || s.losses.is_empty() => {
            // The kill landed after the victim's last involvement (or
            // never fired): indistinguishable from a healthy run.
            if s.param_bytes != rf.bytes {
                f.note(format!("{id}: loss-free completion but bytes differ from reference"));
            }
            for l in &r.leftovers {
                f.note(format!("{id}: orphan: {l}"));
            }
            None
        }
        (Ok(s), FaultPolicy::Redistribute { .. }) => {
            if s.losses != [victim] {
                f.note(format!(
                    "{id}: absorbed losses {:?}, expected [{victim}]",
                    s.losses
                ));
            }
            // A split-invariant reduce (element-wise, disjoint support)
            // makes the survivors' run byte-identical to the full one.
            if rf.split_invariant && s.param_bytes != rf.bytes {
                f.note(format!(
                    "{id}: redistributed result differs from the reference \
                     on a split-invariant problem"
                ));
            }
            if s.iterations != rf.iters {
                f.note(format!(
                    "{id}: redistributed run did {} iterations, reference {}",
                    s.iterations, rf.iters
                ));
            }
            for l in &r.leftovers {
                f.note(format!("{id}: orphan after redistribute: {l}"));
            }
            None
        }
        (Ok(_), FaultPolicy::Abort | FaultPolicy::RestartFromCheckpoint) => {
            // `losses` non-empty is unreachable here (the policies never
            // absorb), so an Ok master with recorded losses is itself a
            // violation.
            f.note(format!("{id}: master absorbed a loss under {policy:?}"));
            None
        }
        (Err((e, ck)), FaultPolicy::Abort | FaultPolicy::RestartFromCheckpoint) => {
            match e {
                BsfError::WorkerLost { rank, .. } if *rank == victim => {}
                other => f.note(format!(
                    "{id}: expected a typed WorkerLost({victim}), got: {other}"
                )),
            }
            // Leftovers are NOT checked on the abort path: the master
            // releases survivors and reports without draining their
            // in-flight folds (documented behavior).
            if matches!(policy, FaultPolicy::RestartFromCheckpoint) {
                if ck.is_none() {
                    f.note(format!("{id}: recoverable loss carried no checkpoint"));
                }
                ck.clone()
            } else {
                None
            }
        }
        (Err((e, _)), FaultPolicy::Redistribute { .. }) => {
            f.note(format!(
                "{id}: redistribute failed to absorb a single loss: {e}"
            ));
            None
        }
    }
}

/// Explore the protocol: every bounded schedule of a healthy world, then
/// (when `vcfg.faults`) a worker kill at every sampled injection point
/// under every fault policy. `mk` builds the model problem — it must be
/// deterministic (same instance every call) and should **not** converge
/// before `vcfg.max_iter`, so all schedules compare at equal depth.
pub fn run_verify<P, F>(mk: F, vcfg: &VerifyConfig) -> VerifyReport
where
    P: BsfProblem,
    F: Fn() -> P + Sync,
{
    let mut f = Findings::new();
    let cfg = BsfConfig::with_workers(vcfg.workers).max_iter(vcfg.max_iter);

    // Canonical reference: the all-defaults schedule of a healthy world.
    let reference = run_schedule(&mk, &cfg, None, &[], None, false);
    check_common(&mut f, "reference", &reference, true);
    let (ref_bytes, ref_iters) = match &reference.master {
        Ok(s) if reference.drive.outcome == SchedOutcome::Completed => {
            (s.param_bytes.clone(), s.iterations)
        }
        Ok(_) => {
            f.note("reference schedule did not complete".to_string());
            return report_early(vcfg, f);
        }
        Err((e, _)) => {
            f.note(format!("reference schedule failed: {e}"));
            return report_early(vcfg, f);
        }
    };
    let canonical: Vec<usize> = reference.drive.trace.iter().map(|c| c.chosen).collect();
    let rounds = reference.drive.rounds;

    // Split-invariance probe: does a (K-1)-worker run produce the same
    // bytes? Only then can Redistribute promise byte-equality after a
    // loss (element-wise reduces with disjoint support do; a float
    // reduction whose grouping shifts with K does not).
    let split_invariant = vcfg.workers >= 2 && {
        let cfg1 = BsfConfig::with_workers(vcfg.workers - 1).max_iter(vcfg.max_iter);
        match run_schedule(&mk, &cfg1, None, &[], None, false).master {
            Ok(s) => s.param_bytes == ref_bytes,
            Err(_) => false,
        }
    };
    let rf = Reference { bytes: ref_bytes, iters: ref_iters, split_invariant };

    // Leg 1: exhaustive fault-free exploration (optionally mutated —
    // then these checks are expected to find violations, proving teeth).
    let mutate = vcfg.mutation == Mutation::DuplicateFold;
    let mut dfs = Dfs::new();
    let mut base_schedules = 0usize;
    let mut truncated = false;
    while let Some(forced) = dfs.frontier().map(|fr| fr.to_vec()) {
        if base_schedules >= vcfg.max_schedules {
            truncated = true;
            break;
        }
        let r = run_schedule(&mk, &cfg, None, &forced, None, mutate);
        base_schedules += 1;
        check_base(&mut f, &format!("schedule #{base_schedules}"), &r, &rf);
        dfs.advance(&r.drive.trace);
    }

    // Leg 2: fault injection along the canonical schedule. Injection
    // points are sampled with a stride so the budget stays bounded;
    // point 0 (pre-run) and the full-depth tail are always included.
    let mut fault_schedules = 0usize;
    let mut abort_losses = 0usize;
    let mut redistribute_losses = 0usize;
    let mut restart_losses = 0usize;
    if vcfg.faults && vcfg.mutation == Mutation::None && vcfg.workers >= 2 {
        let stride = (rounds / 8).max(1);
        let policies = [
            FaultPolicy::Abort,
            FaultPolicy::Redistribute { max_losses: 1 },
            FaultPolicy::RestartFromCheckpoint,
        ];
        for policy in policies {
            let pcfg = cfg.clone().fault(policy);
            for victim in 0..vcfg.workers {
                let mut at = 0usize;
                while at <= rounds {
                    let id = format!("fault {policy:?} victim={victim} round={at}");
                    let r = run_schedule(
                        &mk,
                        &pcfg,
                        None,
                        &canonical,
                        Some(FaultPlan { victim, at_round: at }),
                        false,
                    );
                    fault_schedules += 1;
                    if r.drive.fault_fired {
                        match policy {
                            FaultPolicy::Abort => abort_losses += 1,
                            FaultPolicy::Redistribute { .. } => redistribute_losses += 1,
                            FaultPolicy::RestartFromCheckpoint => restart_losses += 1,
                        }
                    }
                    let ck = check_fault(&mut f, &id, &policy, victim, &r, &rf);
                    // Restart generation 1: relaunch at full K from the
                    // checkpoint (what the one-shot run loop does) — it
                    // must complete bit-identically to the reference.
                    if let Some(ck) = ck {
                        let gid = format!("{id} restart-gen1");
                        let g1 = run_schedule(&mk, &pcfg, Some(ck), &[], None, false);
                        fault_schedules += 1;
                        check_base(&mut f, &gid, &g1, &rf);
                    }
                    at += stride;
                }
            }
        }
    }

    VerifyReport {
        workers: vcfg.workers,
        reference_iterations: ref_iters,
        base_schedules,
        fault_schedules,
        abort_losses,
        redistribute_losses,
        restart_losses,
        truncated,
        split_invariant,
        violations: f.into_violations(),
    }
}

fn report_early(vcfg: &VerifyConfig, f: Findings) -> VerifyReport {
    VerifyReport {
        workers: vcfg.workers,
        reference_iterations: 0,
        base_schedules: 1,
        fault_schedules: 0,
        abort_losses: 0,
        redistribute_losses: 0,
        restart_losses: 0,
        truncated: false,
        split_invariant: false,
        violations: f.into_violations(),
    }
}
