//! Running one real master/worker world under the scheduler, and
//! enumerating its schedules depth-first.
//!
//! [`run_schedule`] spawns the *actual* production state machines —
//! [`MasterLoop`] and [`run_worker_guarded`], the same code every
//! engine executes — over [`VerifyEndpoint`](super::vcomm::VerifyEndpoint)s,
//! drives one bounded interleaving to completion, and reports everything
//! the checker's invariants need: the master's final parameter bytes,
//! per-thread errors, scheduler route checks, and the orphan report.
//!
//! [`Dfs`] turns the scheduler's recorded decision trace into systematic
//! exploration: replay the longest prefix whose last decision still has
//! an untried alternative, bump it, and let the defaults fill the rest —
//! classic stateless model checking (VeriSoft-style), made deterministic
//! by the virtual transport.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;

use crate::error::BsfError;
use crate::skeleton::backend::FusedNativeBackend;
use crate::skeleton::config::BsfConfig;
use crate::skeleton::driver::Checkpoint;
use crate::skeleton::master::MasterLoop;
use crate::skeleton::problem::BsfProblem;
use crate::skeleton::worker::run_worker_guarded;
use crate::transport::{Communicator, FrameBuf, Message, Tag, TransportStats};
use crate::util::codec::Codec;
use crate::verify::vcomm::{Choice, DriveResult, FaultPlan, World};

/// What the master state machine produced on a completed run.
#[derive(Debug, Clone)]
pub struct MasterSummary {
    /// `Codec` encoding of the final approximation — byte-for-byte
    /// comparable across schedules (the determinism invariant).
    pub param_bytes: Vec<u8>,
    /// Iterations the master ran.
    pub iterations: usize,
    /// Physical ranks lost mid-run (fault-injection schedules).
    pub losses: Vec<usize>,
}

/// Everything one explored schedule observed.
pub struct ScheduleResult<Param> {
    /// The raw drive outcome (schedule, outcome, stats).
    pub drive: DriveResult,
    /// The master's verdict; an error carries the inter-iteration
    /// checkpoint (what `RestartFromCheckpoint` would resume from).
    pub master: Result<MasterSummary, (BsfError, Option<Checkpoint<Param>>)>,
    /// `(rank, error)` for each worker loop that failed.
    pub worker_errors: Vec<(usize, String)>,
    /// Orphaned messages at live ranks after the run (mailboxes and
    /// in-flight channels). A clean run leaves none.
    pub leftovers: Vec<String>,
    /// Threads that panicked (a drain assertion or a checker bug).
    pub panics: usize,
}

/// Seeded test mutation: the wrapped endpoint sends its first `Fold`
/// **twice** — the PR 5 bug class, where a double-sent fold silently
/// desynchronizes the master's selective per-rank gather. The checker
/// must flag every schedule of a mutated world (stray-fold error,
/// orphaned message, or a wrong final parameter).
pub struct DuplicateFold<C: Communicator> {
    inner: C,
    fired: AtomicBool,
}

impl<C: Communicator> DuplicateFold<C> {
    /// Wrap worker 0's endpoint with the seeded duplicate-fold bug.
    pub fn new(inner: C) -> Self {
        Self { inner, fired: AtomicBool::new(false) }
    }
}

impl<C: Communicator> Communicator for DuplicateFold<C> {
    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn size(&self) -> usize {
        self.inner.size()
    }

    fn send_frame(&self, to: usize, tag: Tag, frame: FrameBuf) -> Result<(), BsfError> {
        if tag == Tag::Fold && !self.fired.swap(true, Ordering::Relaxed) {
            // `FrameBuf::clone` is a reference bump: the duplicate shares
            // the original's bytes, exactly like a re-sent wire frame.
            self.inner.send_frame(to, tag, frame.clone())?;
        }
        self.inner.send_frame(to, tag, frame)
    }

    fn recv_tags(&self, from: Option<usize>, tags: &[Tag]) -> Result<Message, BsfError> {
        self.inner.recv_tags(from, tags)
    }

    fn try_recv_tags(&self, from: Option<usize>, tags: &[Tag]) -> Option<Message> {
        self.inner.try_recv_tags(from, tags)
    }

    fn stats(&self) -> Arc<TransportStats> {
        self.inner.stats()
    }

    fn undrained(&self) -> Vec<(usize, Tag)> {
        self.inner.undrained()
    }
}

/// Run the production master/worker state machines through ONE schedule.
///
/// * `mk` builds the problem instance — called once per thread, so the
///   problem type needs neither `Clone` nor cross-thread sharing (it is
///   `Send + Sync` anyway, but per-thread instances mirror how real
///   MPI processes each construct their own).
/// * `forced` replays a decision prefix (see [`Dfs`]).
/// * `fault` optionally kills one worker at a scheduler round.
/// * `mutate` wraps worker 0 in [`DuplicateFold`].
pub fn run_schedule<P, F>(
    mk: &F,
    cfg: &BsfConfig,
    start: Option<Checkpoint<P::Param>>,
    forced: &[usize],
    fault: Option<FaultPlan>,
    mutate: bool,
) -> ScheduleResult<P::Param>
where
    P: BsfProblem,
    F: Fn() -> P + Sync,
{
    let k = cfg.workers;
    let world = World::new(k);
    let mut eps = world.endpoints();
    let master_ep = match eps.pop() {
        Some(ep) => ep,
        None => unreachable!("World::new always yields at least the master endpoint"),
    };

    let (drive, worker_results, master) = thread::scope(|s| {
        let mut worker_handles = Vec::with_capacity(k);
        for (rank, ep) in eps.into_iter().enumerate() {
            let w = Arc::clone(&world);
            let wcfg = cfg.clone();
            worker_handles.push(s.spawn(move || {
                let _g = w.register(rank);
                let p = mk();
                let comm: Box<dyn Communicator> = if mutate && rank == 0 {
                    Box::new(DuplicateFold::new(ep))
                } else {
                    Box::new(ep)
                };
                run_worker_guarded(&p, &FusedNativeBackend, &*comm, &wcfg)
                    .map(|_| ())
                    .map_err(|e| e.to_string())
            }));
        }

        let mw = Arc::clone(&world);
        let mcfg = cfg.clone();
        let mh = s.spawn(move || {
            let _g = mw.register(k);
            let p = mk();
            let mut m = match MasterLoop::new(&p, &mcfg, start) {
                Ok(m) => m,
                Err(e) => return Err((e, None)),
            };
            loop {
                match m.step_comm(&p, &master_ep) {
                    Ok(ev) if ev.stop.is_some() => {
                        let out = m.outcome();
                        return Ok(MasterSummary {
                            param_bytes: out.param.to_bytes(),
                            iterations: out.iterations,
                            losses: out.losses,
                        });
                    }
                    Ok(_) => {}
                    Err(e) => {
                        // Capture the resume point first: release() is a
                        // best-effort broadcast and never changes it.
                        let ck = m.checkpoint();
                        m.release(&master_ep);
                        return Err((e, Some(ck)));
                    }
                }
            }
        });

        let drive = world.drive(forced, fault);
        let worker_results: Vec<_> =
            worker_handles.into_iter().map(|h| h.join()).collect();
        (drive, worker_results, mh.join())
    });

    let mut panics = 0usize;
    let mut worker_errors = Vec::new();
    for (rank, res) in worker_results.into_iter().enumerate() {
        match res {
            Ok(Ok(())) => {}
            Ok(Err(e)) => worker_errors.push((rank, e)),
            Err(payload) => {
                panics += 1;
                let what = panic_text(&payload);
                worker_errors.push((rank, format!("worker thread panicked: {what}")));
            }
        }
    }
    let master = match master {
        Ok(r) => r,
        Err(payload) => {
            panics += 1;
            let what = panic_text(&payload);
            Err((BsfError::transport(format!("master thread panicked: {what}")), None))
        }
    };

    ScheduleResult { drive, master, worker_errors, leftovers: world.leftovers(), panics }
}

fn panic_text(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<&str>()
        .map(|s| s.to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "non-string panic payload".to_string())
}

/// Depth-first schedule enumeration over the scheduler's decision
/// traces.
///
/// Feed every run's recorded trace back through [`advance`](Self::advance);
/// [`frontier`](Self::frontier) then yields the forced prefix of the next
/// unexplored schedule, or `None` once the tree is exhausted. Because a
/// prefix determines the world state at its end, trying every `chosen`
/// value at every reachable decision node enumerates every schedule the
/// scheduler distinguishes.
pub struct Dfs {
    frontier: Option<Vec<usize>>,
}

impl Default for Dfs {
    fn default() -> Self {
        Self::new()
    }
}

impl Dfs {
    /// Fresh DFS starting at the all-defaults schedule.
    pub fn new() -> Self {
        Self { frontier: Some(Vec::new()) }
    }

    /// Forced prefix for the next schedule (`None` = tree exhausted).
    pub fn frontier(&self) -> Option<&[usize]> {
        self.frontier.as_deref()
    }

    /// Record the decision trace a run actually took and move to the
    /// next schedule: drop exhausted tail decisions, bump the deepest
    /// one with an untried alternative.
    pub fn advance(&mut self, trace: &[Choice]) {
        let mut stack: Vec<Choice> = trace.to_vec();
        while let Some(last) = stack.last() {
            if last.chosen + 1 < last.arity {
                break;
            }
            stack.pop();
        }
        self.frontier = match stack.last_mut() {
            None => None,
            Some(last) => {
                last.chosen += 1;
                Some(stack.iter().map(|c| c.chosen).collect())
            }
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Simulate a world whose every run makes `depth` binary decisions:
    /// the DFS must visit exactly 2^depth distinct forced prefixes.
    #[test]
    fn dfs_enumerates_a_binary_tree_exactly_once() {
        let depth = 4;
        let mut dfs = Dfs::new();
        let mut seen = Vec::new();
        while let Some(forced) = dfs.frontier().map(|f| f.to_vec()) {
            // "Run": every decision is binary; forced prefix, then 0s.
            let trace: Vec<Choice> = (0..depth)
                .map(|i| Choice { chosen: forced.get(i).copied().unwrap_or(0), arity: 2 })
                .collect();
            let leaf: Vec<usize> = trace.iter().map(|c| c.chosen).collect();
            assert!(!seen.contains(&leaf), "schedule visited twice: {leaf:?}");
            seen.push(leaf);
            dfs.advance(&trace);
        }
        assert_eq!(seen.len(), 1 << depth);
    }

    #[test]
    fn dfs_handles_mixed_arities_and_empty_traces() {
        // Arity sequence 3 then 2 → 6 schedules; a world with no
        // decisions at all → exactly one schedule.
        let mut dfs = Dfs::new();
        let mut count = 0;
        while let Some(forced) = dfs.frontier().map(|f| f.to_vec()) {
            let trace = vec![
                Choice { chosen: forced.first().copied().unwrap_or(0), arity: 3 },
                Choice { chosen: forced.get(1).copied().unwrap_or(0), arity: 2 },
            ];
            count += 1;
            dfs.advance(&trace);
        }
        assert_eq!(count, 6);

        let mut dfs = Dfs::new();
        let mut count = 0;
        while dfs.frontier().is_some() {
            count += 1;
            dfs.advance(&[]);
        }
        assert_eq!(count, 1);
    }
}
