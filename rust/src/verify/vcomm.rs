//! The model checker's virtual transport: a [`Communicator`] whose
//! message deliveries happen only when a deterministic scheduler says
//! so.
//!
//! Every endpoint shares one [`World`]. A `send` never delivers — it
//! queues the message on the in-flight channel `(src, dst)`. A blocking
//! `recv` scans only the endpoint's *mailbox* (messages the scheduler
//! already delivered) and otherwise parks the thread. The scheduler
//! ([`World::drive`]) waits until the whole system is quiescent (every
//! registered thread parked or finished), then picks which channel
//! delivers next:
//!
//! * channels that are the *only* pending source for their destination
//!   are delivered wholesale (same-channel messages are FIFO — MPI's
//!   non-overtaking rule — so no interleaving is lost), and
//! * when several sources contend for one destination, delivering one
//!   message from one of them is a **decision point**: the arity and the
//!   choice taken are recorded in the schedule trace, and the explorer
//!   replays prefixes with different choices to enumerate every bounded
//!   interleaving.
//!
//! This partial-order reduction is sound for the BSF skeleton because
//! receivers only ever observe their own mailbox through selective
//! receive (per-source FIFO) and existence polls (`try_recv_tags`): the
//! relative arrival order of messages from *different* sources is
//! observable only where the destination is contended — exactly where
//! the scheduler branches.
//!
//! Fault injection: the scheduler can kill a worker rank at a chosen
//! decision round. A dead rank's in-flight traffic vanishes (as with a
//! torn TCP peer), its parked thread is woken into a typed error, and
//! peers that address it get [`BsfError::WorkerLost`] — the same
//! contract as the real transports, so `FaultPolicy` recovery paths run
//! unmodified under the checker.
//!
//! Determinism: between two quiescent points each thread runs its own
//! deterministic state machine and only appends to per-channel FIFO
//! queues, so the world state at every quiescent point — and therefore
//! the whole run — is a pure function of the decision sequence.

use std::collections::{BTreeMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::error::BsfError;
use crate::transport::{tags, Communicator, FrameBuf, Message, Tag, TransportStats};

/// How long either side waits before declaring the system wedged. Only
/// reached when a thread is neither parked in this transport nor making
/// progress (a real livelock/hang, not a model-level deadlock — those
/// are detected structurally, instantly).
const WATCHDOG: Duration = Duration::from_secs(10);

/// Condvar re-check interval (wake-ups are explicit; this only bounds
/// watchdog latency).
const POLL: Duration = Duration::from_millis(25);

/// One scheduler decision: which of `arity` contending sources was
/// delivered. The explorer replays a prefix of these and then takes
/// first-choice (`0`) defaults to enumerate schedules depth-first.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Choice {
    /// Index of the alternative taken.
    pub chosen: usize,
    /// How many alternatives were available at this point.
    pub arity: usize,
}

/// Kill `victim` at decision round `at_round` (fires at the first
/// quiescent point with `rounds >= at_round`; if the run ends first the
/// plan reports `fault_fired == false`).
#[derive(Debug, Clone, Copy)]
pub struct FaultPlan {
    /// Rank to kill.
    pub victim: usize,
    /// Order round (0-based) at which the kill fires.
    pub at_round: usize,
}

/// How a driven schedule ended.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SchedOutcome {
    /// Every registered thread finished.
    Completed,
    /// Threads parked forever with nothing deliverable.
    Deadlock(String),
    /// Watchdog expired without reaching quiescence.
    Hang(String),
}

/// Everything one `drive` observed.
#[derive(Debug, Clone)]
pub struct DriveResult {
    /// How the schedule terminated.
    pub outcome: SchedOutcome,
    /// The decision sequence actually taken (replay it to reproduce).
    pub trace: Vec<Choice>,
    /// Total decision rounds (also the space of fault-injection points).
    pub rounds: usize,
    /// Whether the fault plan's kill actually fired.
    pub fault_fired: bool,
    /// Messages delivered to a role that never receives their tag.
    pub misrouted: Vec<String>,
}

struct WorldState {
    /// Delivered-but-not-received messages, per destination rank.
    mailboxes: Vec<VecDeque<Message>>,
    /// Sent-but-not-delivered messages, per (src, dst) channel (BTreeMap
    /// so scheduler iteration order is deterministic).
    in_flight: BTreeMap<(usize, usize), VecDeque<Message>>,
    dead: Vec<bool>,
    /// Per-rank "thread finished" flags (kills only target live threads).
    done: Vec<bool>,
    /// Set on any scheduler exit that leaves threads parked: every
    /// transport call errors out so the run unwinds promptly.
    aborting: bool,
    entered: usize,
    finished: usize,
    blocked: usize,
    /// Bumped on every delivery/kill/abort; parked threads wait on it.
    epoch: u64,
}

/// The shared world all [`VerifyEndpoint`]s live in.
pub struct World {
    size: usize,
    state: Mutex<WorldState>,
    /// Scheduler waits here for quiescence.
    sched_cv: Condvar,
    /// Parked threads wait here for an epoch change.
    thread_cv: Condvar,
    stats: Arc<TransportStats>,
}

/// RAII registration of one endpoint thread; dropping it (return *or*
/// unwind) marks the rank finished and wakes the scheduler.
pub struct ThreadGuard {
    world: Arc<World>,
    rank: usize,
}

impl Drop for ThreadGuard {
    fn drop(&mut self) {
        let mut st = self.world.lock();
        st.finished += 1;
        if self.rank < st.done.len() {
            st.done[self.rank] = true;
        }
        self.world.sched_cv.notify_all();
    }
}

impl World {
    /// A world of `workers + 1` ranks (master last, as everywhere).
    pub fn new(workers: usize) -> Arc<Self> {
        let size = workers + 1;
        Arc::new(Self {
            size,
            state: Mutex::new(WorldState {
                mailboxes: (0..size).map(|_| VecDeque::new()).collect(),
                in_flight: BTreeMap::new(),
                dead: vec![false; size],
                done: vec![false; size],
                aborting: false,
                entered: 0,
                finished: 0,
                blocked: 0,
                epoch: 0,
            }),
            sched_cv: Condvar::new(),
            thread_cv: Condvar::new(),
            stats: Arc::new(TransportStats::default()),
        })
    }

    /// The K+1 endpoints (master is the last one).
    pub fn endpoints(self: &Arc<Self>) -> Vec<VerifyEndpoint> {
        (0..self.size)
            .map(|rank| VerifyEndpoint { rank, world: Arc::clone(self) })
            .collect()
    }

    /// Register the calling thread as rank `rank`'s driver. Must be the
    /// first thing each endpoint thread does.
    pub fn register(self: &Arc<Self>, rank: usize) -> ThreadGuard {
        let mut st = self.lock();
        st.entered += 1;
        self.sched_cv.notify_all();
        drop(st);
        ThreadGuard { world: Arc::clone(self), rank }
    }

    /// Poison-tolerant lock: an assertion failure in one thread must not
    /// cascade into opaque poison panics everywhere else.
    fn lock(&self) -> MutexGuard<'_, WorldState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn kill(st: &mut WorldState, victim: usize) -> bool {
        if victim >= st.dead.len() || st.dead[victim] || st.done[victim] {
            return false;
        }
        st.dead[victim] = true;
        st.mailboxes[victim].clear();
        st.in_flight.retain(|&(s, d), _| s != victim && d != victim);
        true
    }

    fn deliver(
        st: &mut WorldState,
        size: usize,
        key: (usize, usize),
        count: Option<usize>,
        misrouted: &mut Vec<String>,
    ) {
        let (_, dst) = key;
        let dst_role =
            if dst + 1 == size { tags::Role::Master } else { tags::Role::Worker };
        let n = match (st.in_flight.get(&key), count) {
            (Some(q), None) => q.len(),
            (Some(q), Some(c)) => c.min(q.len()),
            (None, _) => 0,
        };
        for _ in 0..n {
            let m = match st.in_flight.get_mut(&key).and_then(|q| q.pop_front()) {
                Some(m) => m,
                None => break,
            };
            match tags::receiver(m.tag) {
                Some(role) if role == dst_role => {}
                Some(role) => misrouted.push(format!(
                    "{:?} from rank {} delivered to rank {dst} ({dst_role:?}), \
                     but its registered receiver role is {role:?}",
                    m.tag, m.from
                )),
                None => misrouted.push(format!(
                    "unregistered tag {:?} from rank {} delivered to rank {dst}",
                    m.tag, m.from
                )),
            }
            st.mailboxes[dst].push_back(m);
        }
    }

    /// Run the scheduler until the world completes, deadlocks or hangs.
    /// `forced` replays a prefix of decisions (out-of-range entries are
    /// clamped to choice 0); decisions beyond the prefix default to 0.
    pub fn drive(&self, forced: &[usize], fault: Option<FaultPlan>) -> DriveResult {
        let mut trace: Vec<Choice> = Vec::new();
        let mut rounds = 0usize;
        let mut fault_fired = false;
        let mut misrouted: Vec<String> = Vec::new();
        let mut st = self.lock();
        loop {
            // Wait for quiescence: all threads registered, none running.
            let deadline = Instant::now() + WATCHDOG;
            loop {
                let running = st.entered - st.finished - st.blocked;
                if st.entered == self.size && running == 0 {
                    break;
                }
                let (g, _) = self
                    .sched_cv
                    .wait_timeout(st, POLL)
                    .unwrap_or_else(|e| e.into_inner());
                st = g;
                if Instant::now() >= deadline {
                    let running = st.entered - st.finished - st.blocked;
                    st.aborting = true;
                    st.epoch += 1;
                    self.thread_cv.notify_all();
                    return DriveResult {
                        outcome: SchedOutcome::Hang(format!(
                            "no quiescence within {WATCHDOG:?} at round {rounds} \
                             ({running} thread(s) still running)"
                        )),
                        trace,
                        rounds,
                        fault_fired,
                        misrouted,
                    };
                }
            }

            if st.entered == st.finished {
                return DriveResult {
                    outcome: SchedOutcome::Completed,
                    trace,
                    rounds,
                    fault_fired,
                    misrouted,
                };
            }

            // Scheduled kill at this decision round.
            if let Some(f) = fault {
                if !fault_fired && rounds >= f.at_round && Self::kill(&mut st, f.victim) {
                    fault_fired = true;
                    st.epoch += 1;
                    self.thread_cv.notify_all();
                    rounds += 1;
                    continue;
                }
            }

            // Deliverable channels: non-empty, destination alive.
            let keys: Vec<(usize, usize)> = st
                .in_flight
                .iter()
                .filter(|&(&(_, d), q)| !q.is_empty() && !st.dead[d])
                .map(|(&k, _)| k)
                .collect();

            if keys.is_empty() {
                // A still-pending kill may be what unsticks the system
                // (a recv on the victim becomes a typed loss).
                if let Some(f) = fault {
                    if !fault_fired && Self::kill(&mut st, f.victim) {
                        fault_fired = true;
                        st.epoch += 1;
                        self.thread_cv.notify_all();
                        rounds += 1;
                        continue;
                    }
                }
                let blocked = st.blocked;
                st.aborting = true;
                st.epoch += 1;
                self.thread_cv.notify_all();
                return DriveResult {
                    outcome: SchedOutcome::Deadlock(format!(
                        "{blocked} thread(s) parked with no deliverable message \
                         at round {rounds}"
                    )),
                    trace,
                    rounds,
                    fault_fired,
                    misrouted,
                };
            }

            // Group pending sources by destination.
            let mut by_dst: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
            for (s, d) in keys {
                by_dst.entry(d).or_default().push(s);
            }
            // Single-source destinations are forced moves: deliver the
            // whole channel (FIFO — no interleaving exists to explore).
            let mut contested: Option<(usize, Vec<usize>)> = None;
            for (d, srcs) in &by_dst {
                if srcs.len() == 1 {
                    Self::deliver(&mut st, self.size, (srcs[0], *d), None, &mut misrouted);
                } else if contested.is_none() {
                    contested = Some((*d, srcs.clone()));
                }
            }
            // The lowest contested destination is the decision point:
            // deliver ONE message from the chosen source.
            if let Some((d, srcs)) = contested {
                let arity = srcs.len();
                let chosen = match forced.get(trace.len()) {
                    Some(&c) if c < arity => c,
                    _ => 0,
                };
                trace.push(Choice { chosen, arity });
                Self::deliver(
                    &mut st,
                    self.size,
                    (srcs[chosen], d),
                    Some(1),
                    &mut misrouted,
                );
            }

            st.epoch += 1;
            self.thread_cv.notify_all();
            rounds += 1;
        }
    }

    /// After a drive: human-readable descriptions of every message still
    /// undelivered or unreceived at a *live* rank. A clean run leaves
    /// none (the orphan invariant).
    pub fn leftovers(&self) -> Vec<String> {
        let st = self.lock();
        let mut out = Vec::new();
        for (rank, mbox) in st.mailboxes.iter().enumerate() {
            if st.dead[rank] {
                continue;
            }
            for m in mbox {
                out.push(format!(
                    "undrained {:?} from rank {} in rank {rank}'s mailbox",
                    m.tag, m.from
                ));
            }
        }
        for (&(s, d), q) in &st.in_flight {
            if st.dead[d] {
                continue;
            }
            for m in q {
                out.push(format!("undelivered {:?} on channel {s} -> {d}", m.tag));
            }
        }
        out
    }
}

/// One rank's endpoint of the scheduler-controlled transport.
pub struct VerifyEndpoint {
    rank: usize,
    world: Arc<World>,
}

fn take_matching(
    mbox: &mut VecDeque<Message>,
    from: Option<usize>,
    tags_: &[Tag],
) -> Option<Message> {
    let idx = mbox.iter().position(|m| {
        tags_.contains(&m.tag) && from.map(|f| m.from == f).unwrap_or(true)
    })?;
    mbox.remove(idx)
}

impl VerifyEndpoint {
    fn aborted(&self) -> BsfError {
        BsfError::transport(format!(
            "rank {}: run aborted by the model-checker scheduler",
            self.rank
        ))
    }

    fn self_dead(&self) -> BsfError {
        BsfError::transport(format!(
            "rank {}: killed by fault injection",
            self.rank
        ))
    }

    fn peer_dead(&self, peer: usize, doing: &str) -> BsfError {
        let reason = format!(
            "rank {}: rank {peer} lost (fault injection) while {doing}",
            self.rank
        );
        // Same per-rank typing rule as the real transports: a vanished
        // worker is a typed loss, a vanished master a generic error.
        if peer + 1 < self.world.size {
            BsfError::worker_lost(peer, reason)
        } else {
            BsfError::transport(reason)
        }
    }
}

impl Communicator for VerifyEndpoint {
    fn rank(&self) -> usize {
        self.rank
    }

    fn size(&self) -> usize {
        self.world.size
    }

    fn send_frame(&self, to: usize, tag: Tag, frame: FrameBuf) -> Result<(), BsfError> {
        let mut st = self.world.lock();
        if st.aborting {
            return Err(self.aborted());
        }
        if to >= self.world.size {
            return Err(BsfError::transport(format!(
                "rank {}: send to rank {to} out of range (size {})",
                self.rank,
                self.world.size
            )));
        }
        if st.dead[self.rank] {
            return Err(self.self_dead());
        }
        if st.dead[to] {
            return Err(self.peer_dead(to, &format!("sending {tag:?}")));
        }
        let len = frame.len();
        st.in_flight
            .entry((self.rank, to))
            .or_default()
            .push_back(Message { from: self.rank, tag, payload: frame });
        self.world.stats.record(tag, len);
        Ok(())
    }

    fn recv_tags(&self, from: Option<usize>, tags_: &[Tag]) -> Result<Message, BsfError> {
        let w = &*self.world;
        let mut st = w.lock();
        loop {
            if st.aborting {
                return Err(self.aborted());
            }
            if st.dead[self.rank] {
                return Err(self.self_dead());
            }
            if let Some(m) = take_matching(&mut st.mailboxes[self.rank], from, tags_) {
                return Ok(m);
            }
            if let Some(f) = from {
                if st.dead[f] {
                    return Err(self.peer_dead(f, &format!("receiving {tags_:?}")));
                }
            }
            // Park until the scheduler delivers something (epoch bump).
            st.blocked += 1;
            w.sched_cv.notify_all();
            let epoch = st.epoch;
            while st.epoch == epoch && !st.aborting {
                let (g, _) = w
                    .thread_cv
                    .wait_timeout(st, POLL)
                    .unwrap_or_else(|e| e.into_inner());
                st = g;
            }
            st.blocked -= 1;
        }
    }

    fn try_recv_tags(&self, from: Option<usize>, tags_: &[Tag]) -> Option<Message> {
        let mut st = self.world.lock();
        if st.aborting || st.dead[self.rank] {
            return None;
        }
        take_matching(&mut st.mailboxes[self.rank], from, tags_)
    }

    fn stats(&self) -> Arc<TransportStats> {
        Arc::clone(&self.world.stats)
    }

    fn undrained(&self) -> Vec<(usize, Tag)> {
        let st = self.world.lock();
        st.mailboxes[self.rank].iter().map(|m| (m.from, m.tag)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn deadlock_is_detected_and_threads_are_released() {
        let world = World::new(1);
        let mut eps = world.endpoints();
        let master = eps.pop().unwrap();
        let worker = eps.pop().unwrap();
        let (out, w_res, m_res) = thread::scope(|s| {
            let ww = Arc::clone(&world);
            let wh = s.spawn(move || {
                let _g = ww.register(0);
                // Waits for an order that never comes.
                worker.recv_tags(Some(1), &[Tag::Order])
            });
            let mw = Arc::clone(&world);
            let mh = s.spawn(move || {
                let _g = mw.register(1);
                // Waits for a fold that never comes.
                master.recv_tags(Some(0), &[Tag::Fold])
            });
            let out = world.drive(&[], None);
            (out, wh.join().unwrap(), mh.join().unwrap())
        });
        assert!(matches!(out.outcome, SchedOutcome::Deadlock(_)), "{:?}", out.outcome);
        assert!(w_res.is_err() && m_res.is_err(), "parked threads released typed");
    }

    #[test]
    fn orphaned_messages_are_reported_as_leftovers() {
        let world = World::new(1);
        let mut eps = world.endpoints();
        let master = eps.pop().unwrap();
        let worker = eps.pop().unwrap();
        let out = thread::scope(|s| {
            let ww = Arc::clone(&world);
            s.spawn(move || {
                let _g = ww.register(0);
                worker.send(1, Tag::Fold, vec![1]).unwrap();
            });
            let mw = Arc::clone(&world);
            s.spawn(move || {
                let _g = mw.register(1);
                drop(master); // never receives
            });
            world.drive(&[], None)
        });
        assert_eq!(out.outcome, SchedOutcome::Completed);
        let left = world.leftovers();
        assert_eq!(left.len(), 1, "{left:?}");
        assert!(left[0].contains("Fold"), "{left:?}");
    }

    #[test]
    fn contested_destination_is_a_recorded_choice_and_forced_replay_holds() {
        // Two workers each send one fold; the master consumes both. The
        // scheduler must record exactly one binary decision, and forcing
        // the other branch must deliver the other source first.
        let run = |forced: &[usize]| {
            let world = World::new(2);
            let mut eps = world.endpoints();
            let master = eps.pop().unwrap();
            let w1 = eps.pop().unwrap();
            let w0 = eps.pop().unwrap();
            thread::scope(|s| {
                for (rank, ep) in [(0usize, w0), (1usize, w1)] {
                    let w = Arc::clone(&world);
                    s.spawn(move || {
                        let _g = w.register(rank);
                        ep.send(2, Tag::Fold, vec![rank as u8]).unwrap();
                    });
                }
                let mw = Arc::clone(&world);
                let mh = s.spawn(move || {
                    let _g = mw.register(2);
                    let a = master.recv_any(Tag::Fold).unwrap();
                    let b = master.recv_any(Tag::Fold).unwrap();
                    (a.from, b.from)
                });
                let out = world.drive(forced, None);
                (out, mh.join().unwrap())
            })
        };
        let (out, order) = run(&[]);
        assert_eq!(out.outcome, SchedOutcome::Completed);
        assert_eq!(out.trace.first().map(|c| c.arity), Some(2));
        assert_eq!(order, (0, 1), "default choice delivers the lowest source");
        let (out, order) = run(&[1]);
        assert_eq!(out.outcome, SchedOutcome::Completed);
        assert_eq!(order.0, 1, "forced choice 1 delivers the other source first");
    }

    #[test]
    fn killed_worker_surfaces_as_typed_loss_on_both_sides() {
        let world = World::new(2);
        let mut eps = world.endpoints();
        let master = eps.pop().unwrap();
        let w1 = eps.pop().unwrap();
        let w0 = eps.pop().unwrap();
        let (out, w0_res, m_res) = thread::scope(|s| {
            let ww = Arc::clone(&world);
            let w0h = s.spawn(move || {
                let _g = ww.register(0);
                // parked forever unless the kill wakes it
                w0.recv_tags(Some(2), &[Tag::Order])
            });
            let ww = Arc::clone(&world);
            s.spawn(move || {
                let _g = ww.register(1);
                drop(w1);
            });
            let mw = Arc::clone(&world);
            let mh = s.spawn(move || {
                let _g = mw.register(2);
                // blocks on the victim: must become a typed loss
                master.recv_tags(Some(0), &[Tag::Fold])
            });
            let out = world.drive(&[], Some(FaultPlan { victim: 0, at_round: 0 }));
            (out, w0h.join().unwrap(), mh.join().unwrap())
        });
        assert_eq!(out.outcome, SchedOutcome::Completed);
        assert!(out.fault_fired);
        assert!(w0_res.is_err(), "victim's own call errors");
        assert!(
            matches!(m_res.unwrap_err(), BsfError::WorkerLost { rank: 0, .. }),
            "master sees a typed per-rank loss"
        );
    }
}
