//! Byte-level codec for message payloads on the MPI-like transport.
//!
//! The paper's skeleton sends raw C structs over MPI; our transport
//! carries `Vec<u8>`, so every order parameter / reduce element type
//! implements [`Codec`]: little-endian, length-prefixed where variable.
//! Kept deliberately tiny — no serde in the offline dependency universe.

/// Encode/decode a value to/from a byte stream.
pub trait Codec: Sized {
    /// Append the encoding of `self` to `buf`.
    fn encode(&self, buf: &mut Vec<u8>);
    /// Decode a value from `buf` starting at `*pos`, advancing `*pos`.
    fn decode(buf: &[u8], pos: &mut usize) -> Self;

    /// Convenience: encode to a fresh buffer.
    fn to_bytes(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        self.encode(&mut buf);
        buf
    }

    /// Convenience: decode a whole buffer.
    fn from_bytes(buf: &[u8]) -> Self {
        let mut pos = 0;
        let v = Self::decode(buf, &mut pos);
        debug_assert_eq!(pos, buf.len(), "trailing bytes after decode");
        v
    }
}

macro_rules! impl_codec_prim {
    ($($t:ty),*) => {$(
        impl Codec for $t {
            fn encode(&self, buf: &mut Vec<u8>) {
                buf.extend_from_slice(&self.to_le_bytes());
            }
            fn decode(buf: &[u8], pos: &mut usize) -> Self {
                const N: usize = std::mem::size_of::<$t>();
                let mut b = [0u8; N];
                b.copy_from_slice(&buf[*pos..*pos + N]);
                *pos += N;
                <$t>::from_le_bytes(b)
            }
        }
    )*};
}

impl_codec_prim!(u8, u16, u32, u64, i8, i16, i32, i64, f32, f64);

impl Codec for usize {
    fn encode(&self, buf: &mut Vec<u8>) {
        (*self as u64).encode(buf);
    }
    fn decode(buf: &[u8], pos: &mut usize) -> Self {
        u64::decode(buf, pos) as usize
    }
}

impl Codec for bool {
    fn encode(&self, buf: &mut Vec<u8>) {
        buf.push(*self as u8);
    }
    fn decode(buf: &[u8], pos: &mut usize) -> Self {
        let v = buf[*pos] != 0;
        *pos += 1;
        v
    }
}

impl Codec for () {
    fn encode(&self, _buf: &mut Vec<u8>) {}
    fn decode(_buf: &[u8], _pos: &mut usize) -> Self {}
}

impl<T: Codec> Codec for Vec<T> {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.len().encode(buf);
        for v in self {
            v.encode(buf);
        }
    }
    fn decode(buf: &[u8], pos: &mut usize) -> Self {
        let n = usize::decode(buf, pos);
        (0..n).map(|_| T::decode(buf, pos)).collect()
    }
}

impl<T: Codec, U: Codec> Codec for (T, U) {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.0.encode(buf);
        self.1.encode(buf);
    }
    fn decode(buf: &[u8], pos: &mut usize) -> Self {
        (T::decode(buf, pos), U::decode(buf, pos))
    }
}

impl<T: Codec, U: Codec, V: Codec> Codec for (T, U, V) {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.0.encode(buf);
        self.1.encode(buf);
        self.2.encode(buf);
    }
    fn decode(buf: &[u8], pos: &mut usize) -> Self {
        (T::decode(buf, pos), U::decode(buf, pos), V::decode(buf, pos))
    }
}

impl<T: Codec, U: Codec, V: Codec, W: Codec> Codec for (T, U, V, W) {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.0.encode(buf);
        self.1.encode(buf);
        self.2.encode(buf);
        self.3.encode(buf);
    }
    fn decode(buf: &[u8], pos: &mut usize) -> Self {
        (
            T::decode(buf, pos),
            U::decode(buf, pos),
            V::decode(buf, pos),
            W::decode(buf, pos),
        )
    }
}

impl<T: Codec> Codec for Option<T> {
    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            None => buf.push(0),
            Some(v) => {
                buf.push(1);
                v.encode(buf);
            }
        }
    }
    fn decode(buf: &[u8], pos: &mut usize) -> Self {
        let tag = buf[*pos];
        *pos += 1;
        match tag {
            0 => None,
            _ => Some(T::decode(buf, pos)),
        }
    }
}

impl<const N: usize> Codec for [f64; N] {
    fn encode(&self, buf: &mut Vec<u8>) {
        for v in self {
            v.encode(buf);
        }
    }
    fn decode(buf: &[u8], pos: &mut usize) -> Self {
        let mut out = [0.0; N];
        for o in &mut out {
            *o = f64::decode(buf, pos);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip<T: Codec + PartialEq + std::fmt::Debug>(v: T) {
        assert_eq!(T::from_bytes(&v.to_bytes()), v);
    }

    #[test]
    fn primitives_roundtrip() {
        roundtrip(42u64);
        roundtrip(-7i32);
        roundtrip(3.25f64);
        roundtrip(true);
        roundtrip(false);
        roundtrip(usize::MAX >> 1);
    }

    #[test]
    fn containers_roundtrip() {
        roundtrip(vec![1.0f64, -2.5, 3.75]);
        roundtrip(Vec::<f64>::new());
        roundtrip((1u32, 2.0f64));
        roundtrip((1usize, vec![0.5f64], true));
        roundtrip((1usize, 2usize, 0.5f64, 3usize));
        roundtrip(Some(vec![1u8, 2, 3]));
        roundtrip(Option::<f64>::None);
        roundtrip([1.0f64, 2.0, 3.0]);
    }

    #[test]
    fn nested_vec_roundtrip() {
        roundtrip(vec![vec![1.0f64, 2.0], vec![], vec![3.0]]);
    }

    #[test]
    fn encoding_is_compact_le() {
        assert_eq!(1.0f64.to_bytes(), 1.0f64.to_le_bytes().to_vec());
        // Vec: 8-byte length prefix + payload
        assert_eq!(vec![0u8; 3].to_bytes().len(), 8 + 3);
    }
}
