//! Small statistics helpers for the bench harness and calibration.

/// Arithmetic mean. Returns 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1). Returns 0.0 for len < 2.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median (interpolated for even length). Returns 0.0 for empty.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Median absolute deviation (robust spread).
pub fn mad(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = median(xs);
    let dev: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&dev)
}

/// p-th percentile (0..=100), nearest-rank on the sorted sample.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Index of the maximum element (first on ties); None if empty.
pub fn argmax(xs: &[f64]) -> Option<usize> {
    xs.iter()
        .enumerate()
        .fold(None, |best: Option<(usize, f64)>, (i, &x)| match best {
            Some((_, bx)) if bx >= x => best,
            _ => Some((i, x)),
        })
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
    }

    #[test]
    fn spread_measures() {
        assert!((stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.138).abs() < 1e-3);
        assert_eq!(mad(&[1.0, 1.0, 2.0, 2.0, 4.0, 6.0, 9.0]), 1.0);
        assert_eq!(stddev(&[1.0]), 0.0);
    }

    #[test]
    fn percentile_and_argmax() {
        let xs = [10.0, 20.0, 30.0, 40.0, 50.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 50.0), 30.0);
        assert_eq!(percentile(&xs, 100.0), 50.0);
        assert_eq!(argmax(&xs), Some(4));
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), Some(1));
        assert_eq!(argmax(&[]), None);
    }
}
