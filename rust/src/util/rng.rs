//! Deterministic PRNG for workload generation and property tests.
//!
//! SplitMix64 (Steele et al., "Fast splittable pseudorandom number
//! generators") — tiny, fast, and good enough for synthetic workloads;
//! implements [`rand_core::RngCore`] so it composes with anything that
//! expects a standard RNG.

use rand_core::{impls, RngCore};

/// SplitMix64 PRNG. Construct with [`SplitMix64::new`] from a seed.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Seeded PRNG (same seed → same stream).
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    /// Next raw 64-bit output.
    pub fn next(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }
}

impl RngCore for SplitMix64 {
    fn next_u32(&mut self) -> u32 {
        (self.next() >> 32) as u32
    }
    fn next_u64(&mut self) -> u64 {
        self.next()
    }
    fn fill_bytes(&mut self, dest: &mut [u8]) {
        impls::fill_bytes_via_next(self, dest)
    }
    fn try_fill_bytes(&mut self, dest: &mut [u8]) -> Result<(), rand_core::Error> {
        self.fill_bytes(dest);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next(), b.next());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next(), b.next());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = SplitMix64::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = SplitMix64::new(9);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = SplitMix64::new(11);
        let xs = r.normal_vec(50_000);
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
