//! Typed CLI argument parsing (the offline build has no clap; this
//! module mirrors clap's `Parser`/`Subcommand` shape — subcommand word,
//! then `--key value` / `--key=value` / `--flag` options — with
//! `Result<_, BsfError::Usage>` everywhere the seed's parser panicked).
//!
//! `main.rs` layers its `Command` enum on top, exactly where a clap
//! derive would sit (see the SNIPPETS exemplar).

use std::collections::BTreeMap;

use crate::error::BsfError;

/// Parsed command line: a subcommand plus `--key value` options and
/// positionals.
#[derive(Debug, Clone, Default)]
pub struct ArgMap {
    /// The leading subcommand word (`run`, `worker`, ...), if any.
    pub subcommand: Option<String>,
    options: BTreeMap<String, String>,
    positionals: Vec<String>,
}

fn bad(key: &str, want: &str, got: &str) -> BsfError {
    BsfError::usage(format!("--{key} expects {want}, got {got:?}"))
}

impl ArgMap {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Self {
        let mut out = ArgMap::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(key) = arg.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap_or_default();
                    out.options.insert(key.to_string(), v);
                } else {
                    out.options.insert(key.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                out.positionals.push(arg);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Raw value of `--key`, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// The i-th positional argument after the subcommand.
    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positionals.get(i).map(|s| s.as_str())
    }

    /// `--key` as a `usize`, or `default` when absent.
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, BsfError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| bad(key, "an integer", v)),
        }
    }

    /// `--key` as a `u64`, or `default` when absent.
    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64, BsfError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| bad(key, "an integer", v)),
        }
    }

    /// `--key` as an `f64`, or `default` when absent.
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, BsfError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| bad(key, "a number", v)),
        }
    }

    /// `--key` as a string, or `default` when absent.
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// True when `--key` was given as a bare flag (or true/1/yes).
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Comma-separated usize list, e.g. `--k 1,2,4,8`.
    pub fn usize_list_or(
        &self,
        key: &str,
        default: &[usize],
    ) -> Result<Vec<usize>, BsfError> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .filter(|s| !s.trim().is_empty())
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|_| bad(key, "comma-separated integers", v))
                })
                .collect(),
        }
    }

    /// Reject option keys not in `known` (typo guard).
    pub fn ensure_known(&self, known: &[&str]) -> Result<(), BsfError> {
        for k in self.options.keys() {
            if !known.contains(&k.as_str()) {
                return Err(BsfError::usage(format!(
                    "unknown option --{k}; known: {known:?}"
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> ArgMap {
        ArgMap::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        // NB: positionals come before flags — a bare positional after a
        // flag would be consumed as that flag's value (documented quirk).
        let a = parse("run jacobi --n 128 --mode=sim --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.usize_or("n", 0).unwrap(), 128);
        assert_eq!(a.str_or("mode", ""), "sim");
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(0), Some("jacobi"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.usize_or("n", 7).unwrap(), 7);
        assert_eq!(a.f64_or("eps", 0.5).unwrap(), 0.5);
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn usize_list() {
        let a = parse("sweep --k 1,2,4,");
        assert_eq!(a.usize_list_or("k", &[]).unwrap(), vec![1, 2, 4]);
        assert_eq!(a.usize_list_or("missing", &[9]).unwrap(), vec![9]);
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse("run --check");
        assert!(a.flag("check"));
    }

    #[test]
    fn unparsable_value_is_usage_error_not_panic() {
        let a = parse("run --n banana");
        let err = a.usize_or("n", 0).unwrap_err();
        assert!(matches!(err, BsfError::Usage(_)), "{err}");
        assert_eq!(err.exit_code(), 2);
    }

    #[test]
    fn unknown_key_is_usage_error() {
        let a = parse("run --typo 3");
        let err = a.ensure_known(&["n"]).unwrap_err();
        assert!(matches!(err, BsfError::Usage(_)), "{err}");
        assert!(err.to_string().contains("typo"));
    }
}
