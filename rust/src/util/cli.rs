//! Tiny CLI argument parser (the offline build has no clap).
//!
//! Supports `program <subcommand> [--key value] [--key=value] [--flag]`.
//! Typed getters with defaults; unknown-key detection for typo safety.

use std::collections::BTreeMap;

/// Parsed command line: a subcommand plus `--key value` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Self {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(key) = arg.strip_prefix("--") {
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.options.insert(key.to_string(), v);
                } else {
                    out.options.insert(key.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).map(|v| v.parse().unwrap_or_else(|_| {
            panic!("--{key} expects an integer, got {v:?}")
        })).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).map(|v| v.parse().unwrap_or_else(|_| {
            panic!("--{key} expects an integer, got {v:?}")
        })).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).map(|v| v.parse().unwrap_or_else(|_| {
            panic!("--{key} expects a number, got {v:?}")
        })).unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// Comma-separated usize list, e.g. `--k 1,2,4,8`.
    pub fn get_usize_list(&self, key: &str, default: &[usize]) -> Vec<usize> {
        match self.get(key) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.trim().is_empty())
                .map(|s| s.trim().parse().unwrap_or_else(|_| {
                    panic!("--{key} expects comma-separated integers, got {v:?}")
                }))
                .collect(),
        }
    }

    /// Panic if any option key is not in `known` (typo guard).
    pub fn expect_known(&self, known: &[&str]) {
        for k in self.options.keys() {
            if !known.contains(&k.as_str()) {
                panic!("unknown option --{k}; known: {known:?}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_options() {
        // NB: positionals come before flags — a bare positional after a
        // flag would be consumed as that flag's value (documented quirk).
        let a = parse("run jacobi --n 128 --mode=sim --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get_usize("n", 0), 128);
        assert_eq!(a.get_str("mode", ""), "sim");
        assert!(a.get_bool("verbose"));
        assert_eq!(a.positional, vec!["jacobi"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.get_usize("n", 7), 7);
        assert_eq!(a.get_f64("eps", 0.5), 0.5);
        assert!(!a.get_bool("verbose"));
    }

    #[test]
    fn usize_list() {
        let a = parse("sweep --k 1,2,4,");
        assert_eq!(a.get_usize_list("k", &[]), vec![1, 2, 4]);
        assert_eq!(a.get_usize_list("missing", &[9]), vec![9]);
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse("run --check");
        assert!(a.get_bool("check"));
    }

    #[test]
    #[should_panic(expected = "unknown option")]
    fn unknown_key_panics() {
        parse("run --typo 3").expect_known(&["n"]);
    }
}
