//! Fixed-point accumulators for grouping-invariant reductions.
//!
//! The skeleton's bit-identity guarantee (same result for every engine
//! and every (K, T) grid) requires the reduce operation ⊕ to be truly
//! associative. `f64` addition is not: the fold tree groups terms
//! differently for different worker counts, so problems whose
//! ReduceElems have *overlapping support* (PageRank rank deltas, k-means
//! partial sums, SGD gradients) cannot carry raw floats. They carry
//! scaled `i64` fixed-point values instead — integer addition is exact
//! and associative, so any fold shape produces the same bits — and
//! convert to/from `f64` only at map-element granularity (each element's
//! contribution is rounded once, deterministically) and on the master.
//!
//! The scale, 2^32, gives ~9 decimal digits of fraction and ±2^31 of
//! integer headroom — ample for normalized ranks, unit-cube coordinates
//! and clipped gradients, and far from `i64` overflow even after
//! millions of summands.

/// Fraction bits of the fixed-point representation.
pub const FIXED_BITS: u32 = 32;

/// The scale factor 2^32 as an `f64`.
pub const FIXED_SCALE: f64 = (1u64 << FIXED_BITS) as f64;

/// Convert an `f64` to fixed-point, rounding to nearest.
#[inline]
pub fn to_fixed(x: f64) -> i64 {
    (x * FIXED_SCALE).round() as i64
}

/// Convert a fixed-point value back to `f64`.
#[inline]
pub fn from_fixed(v: i64) -> f64 {
    v as f64 / FIXED_SCALE
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_is_close() {
        for &x in &[0.0, 1.0, -1.0, 0.3333333333, -2.718281828, 1e-6] {
            assert!((from_fixed(to_fixed(x)) - x).abs() < 1.0 / FIXED_SCALE);
        }
    }

    #[test]
    fn integer_sums_are_grouping_invariant() {
        // The property f64 lacks: ((a+b)+c) == (a+(b+c)) exactly.
        let vals: Vec<i64> =
            (0..100).map(|i| to_fixed((i as f64) * 0.1 - 3.7)).collect();
        let left: i64 = vals.iter().sum();
        let right: i64 = vals.iter().rev().sum();
        let pairs: i64 = vals.chunks(7).map(|c| c.iter().sum::<i64>()).sum();
        assert_eq!(left, right);
        assert_eq!(left, pairs);
    }
}
