//! Minimal property-testing harness (the offline build has no proptest).
//!
//! [`qcheck`] runs a property over `cases` deterministic PRNG streams; on
//! failure it panics with the failing case index and seed so the case can
//! be replayed exactly with [`qcheck_seed`]. No shrinking — properties in
//! this repo draw small sizes to keep counterexamples readable.

use crate::util::rng::SplitMix64;

/// Base seed mixed with the case index (stable across runs).
pub const BASE_SEED: u64 = 0xB5F_5EED;

/// Run `prop` over `cases` independent PRNGs. Panics on the first failure
/// with a replayable seed.
pub fn qcheck(cases: usize, prop: impl Fn(&mut SplitMix64)) {
    for case in 0..cases {
        let seed = BASE_SEED ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = SplitMix64::new(seed);
            prop(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Replay one property case with an explicit seed.
pub fn qcheck_seed(seed: u64, mut prop: impl FnMut(&mut SplitMix64)) {
    let mut rng = SplitMix64::new(seed);
    prop(&mut rng);
}

/// Draw a size in [lo, hi] (inclusive) — the common generator shape.
pub fn size_in(rng: &mut SplitMix64, lo: usize, hi: usize) -> usize {
    lo + rng.below(hi - lo + 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = std::cell::Cell::new(0usize);
        qcheck(25, |_rng| {
            count.set(count.get() + 1);
        });
        assert_eq!(count.get(), 25);
        let _ = &mut count;
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn failing_property_reports_case() {
        qcheck(10, |rng| {
            // fails eventually: not every u64 is even
            assert_eq!(rng.next() % 2, 0);
        });
    }

    #[test]
    fn size_in_respects_bounds() {
        qcheck(50, |rng| {
            let s = size_in(rng, 3, 9);
            assert!((3..=9).contains(&s));
        });
    }

    #[test]
    fn replay_is_deterministic() {
        let mut seen = Vec::new();
        qcheck_seed(0xDEAD, |rng| seen.push(rng.next()));
        let mut seen2 = Vec::new();
        qcheck_seed(0xDEAD, |rng| seen2.push(rng.next()));
        assert_eq!(seen, seen2);
    }
}
