//! Minimal JSON reader/writer (the offline dependency universe has no
//! serde; see Cargo.toml). Used by the machine-readable bench harness
//! (`bsf bench` → `BENCH_*.json`) and its CI comparison mode.
//!
//! Scope: the full JSON value grammar, UTF-8 input, `\uXXXX` escapes
//! (surrogate pairs included). Numbers are `f64` — integers round-trip
//! exactly up to 2^53, far beyond any iteration count or byte total we
//! record. Objects preserve insertion order so emitted files are stable
//! under re-generation (diff-friendly baselines).

use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (integers round-trip exactly up to 2^53).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// Insertion-ordered (not sorted): stable, diff-friendly output.
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object field lookup (first match); `None` on non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// The number inside a `Num`, else `None`.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// A `Num` as an exact non-negative integer, else `None`.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 && *v <= 2f64.powi(53) => {
                Some(*v as u64)
            }
            _ => None,
        }
    }

    /// The string inside a `Str`, else `None`.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    /// The bool inside a `Bool`, else `None`.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The items inside an `Arr`, else `None`.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items.as_slice()),
            _ => None,
        }
    }

    /// Build an object from key/value pairs.
    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Serialize with 2-space indentation and a trailing newline.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    /// Serialize on a single line with no whitespace — the JSONL form
    /// the event stream (`bsf-events/1`) and the `/events` endpoint
    /// emit, one value per line.
    pub fn compact(&self) -> String {
        let mut out = String::new();
        self.write_compact(&mut out);
        out
    }

    fn write_compact(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(v) => write_num(out, *v),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write_compact(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write_compact(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(v) => write_num(out, *v),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) if items.is_empty() => out.push_str("[]"),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(out, indent + 1);
                    item.write_pretty(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push(']');
            }
            Json::Obj(fields) if fields.is_empty() => out.push_str("{}"),
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    push_indent(out, indent + 1);
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 1);
                }
                out.push('\n');
                push_indent(out, indent);
                out.push('}');
            }
        }
    }

    /// Parse a JSON document (exactly one value + optional whitespace).
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        skip_ws(bytes, &mut pos);
        let value = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing characters at byte {pos}"));
        }
        Ok(value)
    }
}

fn push_indent(out: &mut String, indent: usize) {
    for _ in 0..indent {
        out.push_str("  ");
    }
}

fn write_num(out: &mut String, v: f64) {
    if !v.is_finite() {
        // JSON has no Inf/NaN; null is the conventional downgrade.
        out.push_str("null");
    } else if v.fract() == 0.0 && v.abs() <= 2f64.powi(53) {
        let _ = write!(out, "{}", v as i64);
    } else {
        // `{}` prints the shortest representation that round-trips.
        let _ = write!(out, "{v}");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(bytes: &[u8], pos: &mut usize) {
    while *pos < bytes.len() && matches!(bytes[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(bytes: &[u8], pos: &mut usize) -> Result<Json, String> {
    match bytes.get(*pos) {
        None => Err("unexpected end of input".to_string()),
        Some(b'n') => parse_lit(bytes, pos, "null", Json::Null),
        Some(b't') => parse_lit(bytes, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(bytes, pos, "false", Json::Bool(false)),
        Some(b'"') => parse_string(bytes, pos).map(Json::Str),
        Some(b'[') => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                skip_ws(bytes, pos);
                items.push(parse_value(bytes, pos)?);
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => return Err(format!("expected ',' or ']' at byte {pos}")),
                }
            }
        }
        Some(b'{') => {
            *pos += 1;
            let mut fields = Vec::new();
            skip_ws(bytes, pos);
            if bytes.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            loop {
                skip_ws(bytes, pos);
                if bytes.get(*pos) != Some(&b'"') {
                    return Err(format!("expected object key at byte {pos}"));
                }
                let key = parse_string(bytes, pos)?;
                skip_ws(bytes, pos);
                if bytes.get(*pos) != Some(&b':') {
                    return Err(format!("expected ':' at byte {pos}"));
                }
                *pos += 1;
                skip_ws(bytes, pos);
                let value = parse_value(bytes, pos)?;
                fields.push((key, value));
                skip_ws(bytes, pos);
                match bytes.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(fields));
                    }
                    _ => return Err(format!("expected ',' or '}}' at byte {pos}")),
                }
            }
        }
        Some(_) => parse_number(bytes, pos),
    }
}

fn parse_lit(bytes: &[u8], pos: &mut usize, lit: &str, value: Json) -> Result<Json, String> {
    if bytes[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(value)
    } else {
        Err(format!("invalid literal at byte {pos} (expected {lit})"))
    }
}

fn parse_number(bytes: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    if bytes.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while *pos < bytes.len()
        && matches!(bytes[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&bytes[start..*pos])
        .map_err(|_| format!("invalid number at byte {start}"))?;
    text.parse::<f64>()
        .map(Json::Num)
        .map_err(|_| format!("invalid number {text:?} at byte {start}"))
}

fn parse_string(bytes: &[u8], pos: &mut usize) -> Result<String, String> {
    debug_assert_eq!(bytes[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    loop {
        match bytes.get(*pos) {
            None => return Err("unterminated string".to_string()),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match bytes.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = parse_hex4(bytes, *pos + 1)?;
                        *pos += 4;
                        let code = if (0xd800..0xdc00).contains(&hi) {
                            // Surrogate pair: expect \uXXXX low half.
                            if bytes.get(*pos + 1) == Some(&b'\\')
                                && bytes.get(*pos + 2) == Some(&b'u')
                            {
                                let lo = parse_hex4(bytes, *pos + 3)?;
                                if !(0xdc00..0xe000).contains(&lo) {
                                    return Err(format!(
                                        "high surrogate followed by \\u{lo:04x}, \
                                         not a low surrogate"
                                    ));
                                }
                                *pos += 6;
                                0x10000 + ((hi - 0xd800) << 10) + (lo - 0xdc00)
                            } else {
                                return Err("lone high surrogate".to_string());
                            }
                        } else {
                            hi
                        };
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(format!("invalid escape at byte {pos}")),
                }
                *pos += 1;
            }
            Some(_) => {
                // Consume one UTF-8 scalar (input is a &str, so slicing
                // at char boundaries is safe via the str API).
                let rest = std::str::from_utf8(&bytes[*pos..])
                    .map_err(|_| format!("invalid utf-8 at byte {pos}"))?;
                let c = rest.chars().next().expect("non-empty by match arm");
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_hex4(bytes: &[u8], at: usize) -> Result<u32, String> {
    if at + 4 > bytes.len() {
        return Err("truncated \\u escape".to_string());
    }
    let text = std::str::from_utf8(&bytes[at..at + 4])
        .map_err(|_| "invalid \\u escape".to_string())?;
    u32::from_str_radix(text, 16).map_err(|_| format!("invalid \\u escape {text:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_a_bench_shaped_document() {
        let doc = Json::obj(vec![
            ("schema", Json::Str("bsf-bench/1".into())),
            ("bootstrap", Json::Bool(false)),
            (
                "records",
                Json::Arr(vec![Json::obj(vec![
                    ("problem", Json::Str("jacobi".into())),
                    ("workers", Json::Num(2.0)),
                    ("wall_seconds", Json::Num(0.001953125)),
                    ("iterations", Json::Num(137.0)),
                ])]),
            ),
        ]);
        let text = doc.pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, doc);
        assert_eq!(back.get("schema").and_then(Json::as_str), Some("bsf-bench/1"));
        let records = back.get("records").and_then(Json::as_arr).unwrap();
        assert_eq!(records[0].get("iterations").and_then(Json::as_u64), Some(137));
        assert_eq!(
            records[0].get("wall_seconds").and_then(Json::as_f64),
            Some(0.001953125)
        );
    }

    #[test]
    fn compact_is_single_line_and_round_trips() {
        let doc = Json::obj(vec![
            ("schema", Json::Str("bsf-events/1".into())),
            ("iter", Json::Num(42.0)),
            ("phases", Json::Arr(vec![Json::Num(0.5), Json::Num(0.25)])),
            ("note", Json::Str("a\nb".into())),
            ("empty_obj", Json::obj(vec![])),
            ("empty_arr", Json::Arr(vec![])),
            ("null", Json::Null),
        ]);
        let line = doc.compact();
        assert!(!line.contains('\n'), "compact output must be one line: {line}");
        assert!(!line.contains(": "), "no space after ':' in compact form");
        assert_eq!(Json::parse(&line).unwrap(), doc);
        assert_eq!(Json::parse(&doc.pretty()).unwrap(), Json::parse(&line).unwrap());
    }

    #[test]
    fn integers_print_without_exponent_or_fraction() {
        let mut s = String::new();
        write_num(&mut s, 137.0);
        assert_eq!(s, "137");
        let mut s = String::new();
        write_num(&mut s, 0.25);
        assert_eq!(s, "0.25");
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Json::parse(r#"{"s": "a\nb\t\"q\" é 😀"}"#).unwrap();
        let s = v.get("s").and_then(Json::as_str).unwrap();
        assert!(s.contains('\n') && s.contains('é') && s.contains('😀'));
    }

    #[test]
    fn rejects_malformed_documents() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn rejects_broken_surrogate_pairs() {
        // Valid pair decodes...
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
        // ... but a high surrogate must be followed by a low one, as an
        // error — never a debug-overflow panic or a garbage character.
        assert!(Json::parse(r#""\ud800A""#).is_err());
        assert!(Json::parse(r#""\ud800\u0041""#).is_err());
        assert!(Json::parse(r#""\ud800\ud800""#).is_err());
    }

    #[test]
    fn object_lookup_and_accessors() {
        let v = Json::parse(r#"{"a": 1, "b": [true, null], "c": -2.5}"#).unwrap();
        assert_eq!(v.get("a").and_then(Json::as_u64), Some(1));
        assert_eq!(v.get("c").and_then(Json::as_f64), Some(-2.5));
        assert_eq!(v.get("c").and_then(Json::as_u64), None);
        let b = v.get("b").and_then(Json::as_arr).unwrap();
        assert_eq!(b[0].as_bool(), Some(true));
        assert_eq!(b[1], Json::Null);
        assert_eq!(v.get("missing"), None);
    }
}
