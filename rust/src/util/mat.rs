//! Dense row-major f64 matrices + generators for the demo problems.
//!
//! Small on purpose: the skeleton's problems need matvec, column/row
//! slicing, norms, and synthetic system generators (diagonally dominant
//! for Jacobi convergence; random consistent systems for Cimmino; random
//! feasible polytopes for the LPP problems).

use crate::util::rng::SplitMix64;

/// Dense row-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Row-major storage, `rows * cols` long.
    pub data: Vec<f64>,
}

impl Mat {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Build element-wise from `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m.data[i * cols + j] = f(i, j);
            }
        }
        m
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Self {
        Self::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 })
    }

    #[inline]
    /// Element `(i, j)`.
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    /// Mutable element `(i, j)`.
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }

    #[inline]
    /// i-th row as a contiguous slice.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// j-th column as a fresh vector (rows are contiguous, columns not).
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self.at(i, j)).collect()
    }

    /// y = A x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        for i in 0..self.rows {
            y[i] = dot(self.row(i), x);
        }
        y
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |i, j| self.at(j, i))
    }

    /// Rows [lo, hi) as a new matrix.
    pub fn row_block(&self, lo: usize, hi: usize) -> Mat {
        assert!(lo <= hi && hi <= self.rows);
        Mat {
            rows: hi - lo,
            cols: self.cols,
            data: self.data[lo * self.cols..hi * self.cols].to_vec(),
        }
    }
}

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Euclidean norm.
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// Squared Euclidean distance ||a - b||^2 (the paper's stop criterion).
pub fn dist2(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// axpy: y += alpha * x.
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Generate a strictly diagonally dominant system `A x* = b` with a known
/// solution `x*` (sufficient condition for Jacobi convergence, per the
/// paper's example section). Returns (A, b, x*).
pub fn gen_diag_dominant(n: usize, seed: u64) -> (Mat, Vec<f64>, Vec<f64>) {
    let mut rng = SplitMix64::new(seed);
    let mut a = Mat::from_fn(n, n, |_, _| rng.range(-1.0, 1.0));
    for i in 0..n {
        let off: f64 = (0..n).filter(|&j| j != i).map(|j| a.at(i, j).abs()).sum();
        // strictly dominant with margin so convergence is comfortably fast
        let sign = if a.at(i, i) >= 0.0 { 1.0 } else { -1.0 };
        *a.at_mut(i, i) = sign * (off + 1.0 + rng.f64());
    }
    let x_star: Vec<f64> = (0..n).map(|_| rng.range(-5.0, 5.0)).collect();
    let b = a.matvec(&x_star);
    (a, b, x_star)
}

/// Jacobi iteration data: C (zero diagonal, c_ij = -a_ij/a_ii) and
/// d (d_i = b_i / a_ii), per the paper's "Example" section.
pub fn jacobi_cd(a: &Mat, b: &[f64]) -> (Mat, Vec<f64>) {
    let n = a.rows;
    let c = Mat::from_fn(n, n, |i, j| {
        if i == j { 0.0 } else { -a.at(i, j) / a.at(i, i) }
    });
    let d = (0..n).map(|i| b[i] / a.at(i, i)).collect();
    (c, d)
}

/// Generate a consistent (solvable) random system for Cimmino: rows are
/// random unit-ish vectors, b = A x*. Returns (A, b, x*).
pub fn gen_consistent(m: usize, n: usize, seed: u64) -> (Mat, Vec<f64>, Vec<f64>) {
    let mut rng = SplitMix64::new(seed);
    let a = Mat::from_fn(m, n, |_, _| rng.normal());
    let x_star: Vec<f64> = (0..n).map(|_| rng.range(-2.0, 2.0)).collect();
    let b = a.matvec(&x_star);
    (a, b, x_star)
}

/// Generate a feasible system of half-spaces `a_i . x <= b_i` that all
/// contain the ball of radius `margin` around `center` (used by the LPP
/// feasibility problem; mirrors the BSF-LPP-Generator companion repo).
pub fn gen_feasible_halfspaces(
    m: usize,
    n: usize,
    center: &[f64],
    margin: f64,
    seed: u64,
) -> (Mat, Vec<f64>) {
    let mut rng = SplitMix64::new(seed);
    let a = Mat::from_fn(m, n, |_, _| rng.normal());
    let mut b = vec![0.0; m];
    for i in 0..m {
        let row = a.row(i);
        // a_i . center + margin * ||a_i|| <= b_i  ⇒ ball inside half-space
        b[i] = dot(row, center) + margin * norm2(row) + rng.f64();
    }
    (a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_identity() {
        let a = Mat::eye(4);
        let x = vec![1.0, 2.0, 3.0, 4.0];
        assert_eq!(a.matvec(&x), x);
    }

    #[test]
    fn transpose_involution() {
        let m = Mat::from_fn(3, 5, |i, j| (i * 5 + j) as f64);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn row_col_consistent() {
        let m = Mat::from_fn(3, 4, |i, j| (10 * i + j) as f64);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0, 13.0]);
        assert_eq!(m.col(2), vec![2.0, 12.0, 22.0]);
    }

    #[test]
    fn row_block_slices() {
        let m = Mat::from_fn(4, 2, |i, j| (i * 2 + j) as f64);
        let b = m.row_block(1, 3);
        assert_eq!(b.rows, 2);
        assert_eq!(b.row(0), m.row(1));
        assert_eq!(b.row(1), m.row(2));
    }

    #[test]
    fn diag_dominant_is_dominant_and_consistent() {
        let (a, b, x_star) = gen_diag_dominant(24, 3);
        for i in 0..24 {
            let off: f64 = (0..24).filter(|&j| j != i).map(|j| a.at(i, j).abs()).sum();
            assert!(a.at(i, i).abs() > off, "row {i} not dominant");
        }
        let r = a.matvec(&x_star);
        for i in 0..24 {
            assert!((r[i] - b[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn jacobi_cd_zero_diag() {
        let (a, b, _) = gen_diag_dominant(8, 5);
        let (c, d) = jacobi_cd(&a, &b);
        for i in 0..8 {
            assert_eq!(c.at(i, i), 0.0);
            assert!((d[i] - b[i] / a.at(i, i)).abs() < 1e-12);
        }
    }

    #[test]
    fn feasible_halfspaces_contain_center() {
        let center = vec![0.5; 6];
        let (a, b) = gen_feasible_halfspaces(40, 6, &center, 0.1, 7);
        for i in 0..40 {
            assert!(dot(a.row(i), &center) <= b[i] + 1e-9, "row {i} violated");
        }
    }

    #[test]
    fn norms_and_axpy() {
        assert_eq!(norm2(&[3.0, 4.0]), 5.0);
        assert_eq!(dist2(&[1.0, 1.0], &[0.0, 0.0]), 2.0);
        let mut y = vec![1.0, 2.0];
        axpy(2.0, &[10.0, 20.0], &mut y);
        assert_eq!(y, vec![21.0, 42.0]);
    }
}
