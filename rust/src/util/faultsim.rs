//! Deterministic fault injection for the real engines — the test
//! harness behind `tests/fault_tolerance.rs` and the chaos CI job.
//!
//! Three tools, usable separately:
//!
//! * [`FaultScript`] + [`FlakyTransport`] — a declarative partition
//!   plan wrapped around the **master's** endpoint. `kill(rank, round)`
//!   makes rank `r` unreachable from order-broadcast round `round` on:
//!   its order is swallowed, its in-flight messages are dropped, and
//!   the next receive surfaces the typed
//!   [`BsfError::WorkerLost`](crate::error::BsfError::WorkerLost) —
//!   exactly the failure shape a torn TCP connection produces, but on
//!   any transport and at a deterministic iteration. `heal(rank,
//!   round)` lifts the partition and synthesizes the worker's
//!   [`TAG_REJOIN`] announcement, driving the master's re-admission
//!   path. The real worker (thread) stays parked the whole time — a
//!   partition, not a murder — and is released by the driver's normal
//!   teardown broadcast.
//! * [`FlakyThreadedEngine`] — the threaded engine with a
//!   [`FlakyTransport`] interposed on the master side: real worker
//!   threads, real transport, injected losses; drop-in wherever an
//!   [`Engine`] goes.
//! * [`DieAfterFolds`] — the **worker-side** child-kill helper for real
//!   OS processes: wraps the worker's endpoint and hard-exits the
//!   process (exit code [`KILLED_EXIT_CODE`]) right before it would
//!   send fold number `budget + 1` — so "kill worker r at iteration i"
//!   is expressed as `--kill-rank r --kill-after-folds i` on the `bsf
//!   worker` command line ([`run_flaky_process_worker`]).

use std::sync::{Arc, Mutex};

use crate::error::BsfError;
use crate::skeleton::backend::MapBackend;
use crate::skeleton::cluster::run_persistent_worker_with;
use crate::skeleton::config::BsfConfig;
use crate::skeleton::driver::{Checkpoint, Driver};
use crate::skeleton::engine::Engine;
use crate::skeleton::fault::TAG_REJOIN;
use crate::skeleton::problem::BsfProblem;
use crate::skeleton::process::run_process_worker_with;
use crate::skeleton::runner::launch_threaded_with;
use crate::transport::{Communicator, FrameBuf, Message, Tag, TransportStats};

/// Exit code a [`DieAfterFolds`]-killed worker process dies with.
pub const KILLED_EXIT_CODE: i32 = 3;

#[derive(Default)]
struct ScriptState {
    /// (rank, round): partition `rank` away at the first order round
    /// `>= round` (0-based; one round per master order broadcast,
    /// including re-broadcasts after a replan).
    kills: Vec<(usize, usize)>,
    /// (rank, round): lift the partition and synthesize REJOIN at the
    /// first order round `>= round`.
    heals: Vec<(usize, usize)>,
    /// Order-broadcast bursts seen so far.
    rounds_started: usize,
    /// True while inside a burst of consecutive `Tag::Order` sends.
    in_order_burst: bool,
    /// Currently partitioned ranks.
    dead: Vec<usize>,
    /// Partitioned ranks whose loss has not yet been surfaced to a
    /// receive.
    unreported: Vec<usize>,
    /// Healed ranks whose REJOIN has not yet been delivered.
    pending_rejoin: Vec<usize>,
}

impl ScriptState {
    /// Called on the first `Tag::Order` send of a burst: arm the kills
    /// and heals scheduled for the new round.
    fn start_round(&mut self) {
        let round = self.rounds_started;
        self.rounds_started += 1;
        let mut i = 0;
        while i < self.kills.len() {
            if self.kills[i].1 <= round {
                let (rank, _) = self.kills.remove(i);
                if !self.dead.contains(&rank) {
                    self.dead.push(rank);
                    self.unreported.push(rank);
                }
            } else {
                i += 1;
            }
        }
        let mut i = 0;
        while i < self.heals.len() {
            if self.heals[i].1 <= round {
                let (rank, _) = self.heals.remove(i);
                if let Some(pos) = self.dead.iter().position(|&d| d == rank) {
                    self.dead.remove(pos);
                    self.unreported.retain(|&u| u != rank);
                    self.pending_rejoin.push(rank);
                }
            } else {
                i += 1;
            }
        }
    }
}

/// A declarative, deterministic partition plan, shared by clones (the
/// test keeps one handle, the engine's transports another).
#[derive(Clone, Default)]
pub struct FaultScript {
    state: Arc<Mutex<ScriptState>>,
}

impl FaultScript {
    /// Empty plan: no kills, no heals.
    pub fn new() -> Self {
        Self::default()
    }

    /// Partition worker `rank` away at order-broadcast round `round`
    /// (0-based): it misses that round's order and the master's next
    /// receive reports it lost.
    pub fn kill(self, rank: usize, round: usize) -> Self {
        self.state.lock().expect("fault script lock").kills.push((rank, round));
        self
    }

    /// Lift `rank`'s partition at round `round` and announce its
    /// [`TAG_REJOIN`] — the master re-admits it at the next iteration
    /// boundary.
    pub fn heal(self, rank: usize, round: usize) -> Self {
        self.state.lock().expect("fault script lock").heals.push((rank, round));
        self
    }

    /// Ranks currently partitioned away (test introspection).
    pub fn dead_ranks(&self) -> Vec<usize> {
        self.state.lock().expect("fault script lock").dead.clone()
    }

    /// Clear the live partition state (dead/unreported/pending-rejoin)
    /// while keeping unfired kills and heals and the round counter. A
    /// `RestartFromCheckpoint` relaunch builds a *fresh* worker set, so
    /// the old generation's partitions must not apply to it.
    pub fn reset_partitions(&self) {
        let mut s = self.state.lock().expect("fault script lock");
        s.dead.clear();
        s.unreported.clear();
        s.pending_rejoin.clear();
        s.in_order_burst = false;
    }
}

/// A [`Communicator`] wrapper applying a [`FaultScript`] to the master's
/// endpoint: swallows traffic to/from partitioned ranks and surfaces
/// their loss typed, like a torn connection would.
pub struct FlakyTransport<C: Communicator> {
    inner: C,
    script: FaultScript,
}

impl<C: Communicator> FlakyTransport<C> {
    /// Wrap `inner` (the master's endpoint) under `script`.
    pub fn new(inner: C, script: FaultScript) -> Self {
        Self { inner, script }
    }

    fn lost(rank: usize) -> BsfError {
        BsfError::worker_lost(rank, "injected fault (partitioned by FaultScript)")
    }
}

impl<C: Communicator> Communicator for FlakyTransport<C> {
    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn size(&self) -> usize {
        self.inner.size()
    }

    fn send_frame(&self, to: usize, tag: Tag, frame: FrameBuf) -> Result<(), BsfError> {
        {
            let mut s = self.script.state.lock().expect("fault script lock");
            if tag == Tag::Order {
                if !s.in_order_burst {
                    s.in_order_burst = true;
                    s.start_round();
                }
            } else {
                s.in_order_burst = false;
            }
            // The partition swallows outbound traffic to a dead rank —
            // except exit flags, which model the driver's teardown
            // broadcast reaching the (really alive, just partitioned)
            // worker thread so it can be joined.
            if s.dead.contains(&to) && tag != Tag::Exit {
                return Ok(());
            }
        }
        self.inner.send_frame(to, tag, frame)
    }

    fn recv_tags(&self, from: Option<usize>, tags: &[Tag]) -> Result<Message, BsfError> {
        loop {
            {
                let mut s = self.script.state.lock().expect("fault script lock");
                // Surface an unreported loss this receive could be
                // waiting on (matches TCP: the loss event lands at the
                // next receive touching the dead peer).
                if let Some(pos) = s
                    .unreported
                    .iter()
                    .position(|&r| from.map(|f| f == r).unwrap_or(true))
                {
                    let r = s.unreported.remove(pos);
                    return Err(Self::lost(r));
                }
                if let Some(f) = from {
                    if s.dead.contains(&f) {
                        // Already reported once; nothing will ever
                        // arrive from a partitioned rank.
                        return Err(Self::lost(f));
                    }
                }
            }
            let m = self.inner.recv_tags(from, tags)?;
            let swallowed = {
                let s = self.script.state.lock().expect("fault script lock");
                s.dead.contains(&m.from)
            };
            if swallowed {
                continue; // straggler from inside the partition: dropped
            }
            return Ok(m);
        }
    }

    fn try_recv_tags(&self, from: Option<usize>, tags: &[Tag]) -> Option<Message> {
        {
            let mut s = self.script.state.lock().expect("fault script lock");
            if tags.contains(&TAG_REJOIN) {
                if let Some(r) = s.pending_rejoin.pop() {
                    return Some(Message {
                        from: r,
                        tag: TAG_REJOIN,
                        payload: FrameBuf::empty(),
                    });
                }
            }
        }
        loop {
            let m = self.inner.try_recv_tags(from, tags)?;
            let swallowed = {
                let s = self.script.state.lock().expect("fault script lock");
                s.dead.contains(&m.from)
            };
            if !swallowed {
                return Some(m);
            }
        }
    }

    fn stats(&self) -> Arc<TransportStats> {
        self.inner.stats()
    }
}

/// The threaded engine with a [`FlakyTransport`] interposed on the
/// master endpoint: real worker threads, real in-process transport,
/// script-injected partitions. `name()` stays `"threaded"` — it *is*
/// the threaded engine, under induced weather.
#[derive(Clone, Default)]
pub struct FlakyThreadedEngine {
    script: FaultScript,
}

impl FlakyThreadedEngine {
    /// Threaded engine that applies `script` to the master endpoint.
    pub fn new(script: FaultScript) -> Self {
        Self { script }
    }
}

impl<P: BsfProblem> Engine<P> for FlakyThreadedEngine {
    fn name(&self) -> &'static str {
        "threaded"
    }

    fn launch(
        &self,
        problem: Arc<P>,
        backend: Arc<dyn MapBackend<P>>,
        cfg: &BsfConfig,
        start: Option<Checkpoint<P::Param>>,
    ) -> Result<Box<dyn Driver<P>>, BsfError> {
        // A relaunch (RestartFromCheckpoint) runs on a fresh worker
        // set: the previous generation's partitions do not carry over.
        self.script.reset_partitions();
        let script = self.script.clone();
        launch_threaded_with(problem, backend, cfg, start, move |ep| {
            Box::new(FlakyTransport::new(ep, script)) as Box<dyn Communicator>
        })
    }
}

/// Worker-side child-kill helper: pass `budget` folds through, then
/// hard-exit the process (code [`KILLED_EXIT_CODE`]) right before
/// sending the next one — a real mid-run worker death at a
/// deterministic iteration.
pub struct DieAfterFolds<C: Communicator> {
    inner: C,
    remaining: Mutex<usize>,
}

impl<C: Communicator> DieAfterFolds<C> {
    /// Let `budget` folds through `inner`, then die.
    pub fn new(inner: C, budget: usize) -> Self {
        Self { inner, remaining: Mutex::new(budget) }
    }
}

impl<C: Communicator> Communicator for DieAfterFolds<C> {
    fn rank(&self) -> usize {
        self.inner.rank()
    }

    fn size(&self) -> usize {
        self.inner.size()
    }

    fn send_frame(&self, to: usize, tag: Tag, frame: FrameBuf) -> Result<(), BsfError> {
        if tag == Tag::Fold {
            let mut left = self.remaining.lock().expect("fold budget lock");
            if *left == 0 {
                eprintln!(
                    "bsf worker {}: injected death before fold (kill-after-folds)",
                    self.inner.rank()
                );
                std::process::exit(KILLED_EXIT_CODE);
            }
            *left -= 1;
        }
        self.inner.send_frame(to, tag, frame)
    }

    fn recv_tags(&self, from: Option<usize>, tags: &[Tag]) -> Result<Message, BsfError> {
        self.inner.recv_tags(from, tags)
    }

    fn try_recv_tags(&self, from: Option<usize>, tags: &[Tag]) -> Option<Message> {
        self.inner.try_recv_tags(from, tags)
    }

    fn stats(&self) -> Arc<TransportStats> {
        self.inner.stats()
    }
}

/// The worker-process entry point with an injected death: exactly
/// `run_process_worker` / `run_persistent_worker` (same connect /
/// handshake / report protocol, via their wrap hooks), with the
/// endpoint wrapped in [`DieAfterFolds`] at the given fold budget.
/// Backs the `bsf worker --kill-rank R --kill-after-folds N` flags.
pub fn run_flaky_process_worker<P: BsfProblem>(
    problem: &P,
    backend: &dyn MapBackend<P>,
    connect: &str,
    rank: usize,
    cfg_template: &BsfConfig,
    die_after_folds: usize,
    persist: bool,
) -> Result<(), BsfError> {
    if persist {
        run_persistent_worker_with(problem, backend, connect, rank, cfg_template, |ep| {
            Box::new(DieAfterFolds::new(ep, die_after_folds)) as Box<dyn Communicator>
        })
    } else {
        run_process_worker_with(problem, backend, connect, rank, cfg_template, |ep| {
            Box::new(DieAfterFolds::new(ep, die_after_folds)) as Box<dyn Communicator>
        })
        .map(|_| ())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::build_thread_transport;
    use crate::util::codec::Codec;

    #[test]
    fn kill_partitions_a_rank_and_reports_once_per_receive() {
        let mut eps = build_thread_transport(2);
        let master = eps.pop().unwrap();
        let w1 = eps.pop().unwrap();
        let w0 = eps.pop().unwrap();
        let script = FaultScript::new().kill(0, 0);
        let flaky = FlakyTransport::new(master, script.clone());

        // First order burst arms the round-0 kill: the order to rank 0
        // is swallowed, rank 1's goes through.
        flaky.send(0, Tag::Order, vec![1]).unwrap();
        flaky.send(1, Tag::Order, vec![1]).unwrap();
        assert_eq!(script.dead_ranks(), vec![0]);
        assert!(w0.try_recv_tags(None, &[Tag::Order]).is_none(), "swallowed");
        assert!(w1.try_recv_tags(None, &[Tag::Order]).is_some(), "delivered");

        // The loss surfaces at the next receive...
        w1.send(2, Tag::Fold, vec![9]).unwrap();
        let err = flaky.recv_tags(Some(0), &[Tag::Fold]).unwrap_err();
        assert!(matches!(err, BsfError::WorkerLost { rank: 0, .. }), "{err}");
        // ...and the live rank's traffic still flows.
        let m = flaky.recv_tags(Some(1), &[Tag::Fold]).unwrap();
        assert_eq!(m.payload, vec![9]);
        // Stragglers from inside the partition are dropped, but exit
        // flags still reach the partitioned (parked) worker.
        flaky.send(0, Tag::Exit, true.to_bytes()).unwrap();
        assert!(w0.try_recv_tags(None, &[Tag::Exit]).is_some());
    }

    #[test]
    fn heal_synthesizes_a_rejoin_announcement() {
        let mut eps = build_thread_transport(1);
        let master = eps.pop().unwrap();
        let _w0 = eps.pop().unwrap();
        let script = FaultScript::new().kill(0, 0).heal(0, 1);
        let flaky = FlakyTransport::new(master, script.clone());

        flaky.send(0, Tag::Order, vec![1]).unwrap(); // round 0: killed
        assert_eq!(script.dead_ranks(), vec![0]);
        assert!(flaky.try_recv_tags(None, &[TAG_REJOIN]).is_none());

        // A non-order send ends the burst; the next order starts round 1.
        flaky.send(0, Tag::Exit, false.to_bytes()).unwrap();
        flaky.send(0, Tag::Order, vec![2]).unwrap(); // round 1: healed
        assert!(script.dead_ranks().is_empty());
        let m = flaky.try_recv_tags(None, &[TAG_REJOIN]).expect("rejoin synthesized");
        assert_eq!((m.from, m.tag), (0, TAG_REJOIN));
        assert!(flaky.try_recv_tags(None, &[TAG_REJOIN]).is_none(), "once");
    }
}
