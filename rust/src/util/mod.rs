//! Support code: PRNG, codec, dense matrices, stats, CLI parsing and the
//! in-tree property-testing harness.

pub mod cli;
pub mod codec;
pub mod mat;
pub mod qcheck;
pub mod rng;
pub mod stats;
