//! Support code: PRNG, codec, dense matrices, stats, CLI parsing, a
//! minimal JSON reader/writer (for the machine-readable bench harness)
//! and the in-tree property-testing harness.

pub mod cli;
pub mod codec;
pub mod json;
pub mod mat;
pub mod qcheck;
pub mod rng;
pub mod stats;
