//! Support code: PRNG, codec, dense matrices, stats, CLI parsing, a
//! minimal JSON reader/writer (for the machine-readable bench harness),
//! the in-tree property-testing harness and the deterministic
//! fault-injection harness ([`faultsim`]).

pub mod cli;
pub mod codec;
pub mod faultsim;
pub mod fixed;
pub mod json;
pub mod mat;
pub mod qcheck;
pub mod rng;
pub mod stats;
