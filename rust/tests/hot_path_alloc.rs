//! Hot-path guard for the skeleton itself: after warm-up, a steady-state
//! BSF iteration on the threaded engine must not touch the heap — on
//! either side of the transport. The master encodes each order into a
//! pooled [`FrameBuf`] slot, the workers re-encode their folds into
//! pooled slots of their own, the mailbox `VecDeque`s keep their
//! capacity, and every wire payload in this test is a fixed-size scalar
//! — so a clean pass allocates nothing, and a deterministic per-iteration
//! allocation (a fresh `Vec` per order, per fold, or per mailbox push)
//! taints every pass.
//!
//! This binary holds only this guard: the counting global allocator sees
//! every thread in the process, so co-resident tests would add noise.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use bsf::skeleton::problem::{BsfProblem, IterCtx, MapCtx};
use bsf::skeleton::{Bsf, BsfConfig, StepDecision, ThreadedEngine};

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

// SAFETY: defers every operation to `System`; only adds a relaxed
// counter bump on the allocating paths.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

/// Scalar relaxation toward the element mean: `Param` and `ReduceElem`
/// are both `f64`, so the order and fold payloads are fixed-size and
/// their codecs allocation-free — the run exercises exactly the pooled
/// frame path and nothing else. Never converges on its own; the stepping
/// test decides when to stop.
struct ScalarRelax {
    n: usize,
}

impl BsfProblem for ScalarRelax {
    type Param = f64;
    type MapElem = f64;
    type ReduceElem = f64;

    fn list_size(&self) -> usize {
        self.n
    }

    fn map_list_elem(&self, i: usize) -> f64 {
        (i % 7) as f64 * 0.125 + 0.25
    }

    fn init_parameter(&self) -> f64 {
        1.0
    }

    fn map_f(&self, elem: &f64, param: &f64, _ctx: &MapCtx) -> Option<f64> {
        Some(elem + param)
    }

    fn reduce_f(&self, x: &f64, y: &f64, _job: usize) -> f64 {
        x + y
    }

    fn process_results(
        &self,
        reduce_result: Option<&f64>,
        _reduce_counter: u64,
        param: &mut f64,
        _ctx: &IterCtx,
    ) -> StepDecision {
        // r = Σ(eᵢ + p) over the whole list, so r/n − p is the element
        // mean; relaxing halfway there converges to a fixed point but
        // never trips an exit — the run stops when the test says so.
        let mean = reduce_result.copied().unwrap_or(0.0) / self.n as f64 - *param;
        *param = 0.5 * (*param + mean);
        StepDecision::stay(0)
    }
}

fn steady_state_is_alloc_free(overlap: bool) {
    let cfg = BsfConfig::with_workers(2).max_iter(1_000_000).overlapped(overlap);
    let mut run = Bsf::new(ScalarRelax { n: 64 })
        .config(cfg)
        .engine(ThreadedEngine)
        .iterate()
        .expect("launch");

    // Warm up: the frame pools reach their steady slot count (a worker
    // holds iteration i's order frame until it starts decoding i+1's, so
    // the master's order pool stabilizes at two slots), the mailbox
    // `VecDeque`s and codec scratch reach capacity.
    for _ in 0..64 {
        run.step().expect("warm-up step");
    }

    // Worker threads run concurrently with the master (and the test
    // harness has housekeeping threads of its own), so accept the guard
    // as passed if any single pass of 32 iterations observes zero
    // allocations — a deterministic per-iteration allocation would
    // taint every pass.
    let mut clean = false;
    for _ in 0..10 {
        let before = ALLOCS.load(Ordering::Relaxed);
        for _ in 0..32 {
            run.step().expect("measured step");
        }
        if ALLOCS.load(Ordering::Relaxed) == before {
            clean = true;
            break;
        }
    }
    let report = run.finish().expect("finish");
    assert!(report.iterations >= 64 + 32, "ran fewer steps than driven");
    assert!(
        clean,
        "a steady-state iteration allocated in every measured pass (overlap={overlap})"
    );
}

// One #[test] driving both configurations sequentially: the harness runs
// tests in the same binary concurrently, and a parallel sibling's
// warm-up allocations would taint this one's measured rounds.
#[test]
fn steady_state_iterations_do_not_allocate() {
    steady_state_is_alloc_free(false);
    steady_state_is_alloc_free(true);
}
