//! Live-telemetry integration: the exporter serves monotone `/metrics`
//! snapshots mid-run, the event stream reaches stderr as `bsf-events/1`
//! JSONL, `bsf top --once` renders a fleet view, stdout stays
//! results-only, and attaching a sink never changes the numerics.

use std::process::Command;
use std::sync::Arc;
use std::time::Duration;

use bsf::costmodel::{calibrate, ClusterProfile};
use bsf::metrics::exporter::{http_get, MetricsExporter};
use bsf::metrics::telemetry::{RunEvent, RunTelemetry};
use bsf::problems::jacobi::JacobiProblem;
use bsf::skeleton::{Bsf, BsfConfig, SerialEngine, ThreadedEngine};
use bsf::transport::VolumeByTag;
use bsf::util::json::Json;

const BSF_BIN: &str = env!("CARGO_BIN_EXE_bsf");

/// Poll `/metrics` between steps of a live threaded run: the iteration
/// counter must be strictly monotone across polls, `/events` must serve
/// parseable `bsf-events/1` lines, and the calibrated cost model must
/// surface predicted phase seconds next to the measured ones.
#[test]
fn exporter_serves_monotone_metrics_mid_run() {
    let (p, _) = JacobiProblem::random(96, 1e-30, 7);
    let sink = Arc::new(RunTelemetry::new());
    let cal = calibrate(&p, ClusterProfile::infiniband(), 2);
    sink.set_cost_model(&cal.params, 2);
    let exporter = MetricsExporter::bind("127.0.0.1:0", Arc::clone(&sink)).unwrap();
    let addr = exporter.addr().to_string();

    let cfg = BsfConfig::with_workers(2)
        .max_iter(50)
        .heartbeat(2)
        .telemetry(Arc::clone(&sink));
    let mut run = Bsf::new(p).config(cfg).engine(ThreadedEngine).iterate().unwrap();

    let mut seen = Vec::new();
    for _ in 0..10 {
        run.step().unwrap();
        let body = http_get(&addr, "/metrics", Duration::from_secs(5)).unwrap();
        let doc = Json::parse(&body).unwrap();
        assert_eq!(doc.get("schema").and_then(Json::as_str), Some("bsf-metrics/1"));
        seen.push(doc.get("iteration").and_then(Json::as_u64).unwrap());
    }
    assert!(
        seen.windows(2).all(|w| w[0] < w[1]),
        "iteration counts not monotone mid-run: {seen:?}"
    );

    // The snapshot carries the predicted-vs-measured phase rows once a
    // cost model is attached.
    let body = http_get(&addr, "/metrics", Duration::from_secs(5)).unwrap();
    let doc = Json::parse(&body).unwrap();
    let phases = doc.get("phases").expect("phases section");
    assert!(phases.get("measured").and_then(|m| m.get("gather")).is_some());
    assert!(
        phases.get("predicted").and_then(|m| m.get("gather")).is_some(),
        "predicted phases missing after set_cost_model"
    );
    assert!(phases
        .get("measured_over_predicted")
        .and_then(|m| m.get("gather"))
        .is_some());

    // /events is bsf-events/1 JSONL, led by run_start, with per-iteration
    // events carrying the prediction.
    let events = http_get(&addr, "/events", Duration::from_secs(5)).unwrap();
    let mut kinds = Vec::new();
    let mut predicted_seen = false;
    for line in events.lines().filter(|l| !l.trim().is_empty()) {
        let e = RunEvent::from_json(&Json::parse(line).unwrap())
            .unwrap_or_else(|err| panic!("{line}: {err}"));
        if let RunEvent::Iteration { predicted: Some(_), .. } = e {
            predicted_seen = true;
        }
        kinds.push(e.kind());
    }
    assert_eq!(kinds.first().copied(), Some("run_start"));
    assert!(kinds.iter().filter(|k| **k == "iteration").count() >= 10);
    assert!(predicted_seen, "no iteration event carried a prediction");

    let report = run.run_to_end().unwrap();
    assert_eq!(report.iterations, 50);
    assert_eq!(sink.iterations(), 50);

    // Heartbeats were configured every 2 folds, so worker health rows
    // must have materialized over 50 iterations.
    let body = http_get(&addr, "/metrics", Duration::from_secs(5)).unwrap();
    let doc = Json::parse(&body).unwrap();
    let health = doc.get("workers_health").and_then(Json::as_arr).unwrap();
    assert!(!health.is_empty(), "no worker heartbeats surfaced: {body}");
    assert_eq!(doc.get("ended").and_then(Json::as_bool), Some(true));
    exporter.shutdown();
}

/// The serial engine has no transport but reports the same stream.
#[test]
fn serial_engine_feeds_the_sink() {
    let sink = Arc::new(RunTelemetry::new());
    let (p, _) = JacobiProblem::random(48, 1e-30, 7);
    let cfg = BsfConfig::with_workers(1).max_iter(7).telemetry(Arc::clone(&sink));
    let r = Bsf::new(p).config(cfg).engine(SerialEngine).run().unwrap();
    assert_eq!(r.iterations, 7);
    assert_eq!(sink.iterations(), 7);
    let m = sink.metrics_json();
    assert_eq!(m.get("engine").and_then(Json::as_str), Some("serial"));
    assert_eq!(m.get("ended").and_then(Json::as_bool), Some(true));
    let kinds: Vec<&'static str> = sink.events().iter().map(|e| e.kind()).collect();
    assert_eq!(kinds.first().copied(), Some("run_start"));
    assert_eq!(kinds.last().copied(), Some("run_end"));
}

/// Attaching telemetry (sink + heartbeats) must not change the numerics:
/// the tap runs after every decision is made.
#[test]
fn results_are_bit_identical_with_telemetry_on() {
    fn solve(telemetry: bool) -> Vec<f64> {
        let (p, _) = JacobiProblem::random(64, 1e-12, 7);
        let mut cfg = BsfConfig::with_workers(3).max_iter(500);
        if telemetry {
            cfg = cfg.heartbeat(2).telemetry(Arc::new(RunTelemetry::new()));
        }
        Bsf::new(p).config(cfg).engine(ThreadedEngine).run().unwrap().param
    }
    let plain = solve(false);
    let tapped = solve(true);
    assert_eq!(plain.len(), tapped.len());
    for (i, (a, b)) in plain.iter().zip(&tapped).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "x[{i}] differs: {a} vs {b}");
    }
}

/// Piped `bsf run` stdout is results-only (`done:` + `result:`);
/// diagnostics live on stderr.
#[test]
fn cli_stdout_is_results_only() {
    let out = Command::new(BSF_BIN)
        .args(["run", "jacobi", "--n", "64", "--k", "2", "--engine", "threaded"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{out:?}");
    let stdout = String::from_utf8(out.stdout).unwrap();
    let stderr = String::from_utf8(out.stderr).unwrap();
    let lines: Vec<&str> = stdout.lines().collect();
    assert_eq!(lines.len(), 2, "unexpected stdout: {stdout}");
    assert!(
        lines[0].starts_with("done: engine=threaded iterations="),
        "{stdout}"
    );
    assert!(lines[1].starts_with("result: ["), "{stdout}");
    assert!(stderr.contains("phases: "), "{stderr}");
    assert!(stderr.contains("traffic: order="), "{stderr}");
}

/// `--events jsonl` streams one schema-versioned event per iteration to
/// stderr without touching stdout.
#[test]
fn cli_events_jsonl_streams_to_stderr() {
    let out = Command::new(BSF_BIN)
        .args([
            "run", "jacobi", "--n", "64", "--k", "2", "--engine", "threaded",
            "--events", "jsonl", "--eps", "1e-30", "--max-iter", "20",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{out:?}");
    let stdout = String::from_utf8(out.stdout).unwrap();
    assert_eq!(stdout.lines().count(), 2, "unexpected stdout: {stdout}");

    let stderr = String::from_utf8(out.stderr).unwrap();
    let mut kinds = Vec::new();
    for line in stderr.lines().filter(|l| l.starts_with('{')) {
        let v = Json::parse(line).unwrap_or_else(|e| panic!("{line}: {e}"));
        assert_eq!(
            v.get("schema").and_then(Json::as_str),
            Some("bsf-events/1"),
            "{line}"
        );
        kinds.push(RunEvent::from_json(&v).unwrap().kind());
    }
    assert!(kinds.contains(&"run_start"), "{stderr}");
    assert_eq!(kinds.iter().filter(|k| **k == "iteration").count(), 20, "{stderr}");
    assert!(kinds.contains(&"run_end"), "{stderr}");
}

/// `bsf top --once` against a live exporter renders the fleet view.
#[test]
fn cli_top_once_renders_fleet_view() {
    let sink = Arc::new(RunTelemetry::new());
    sink.run_start("threaded", 2);
    sink.record_iteration(1, 0.5, [0.5, 0.25, 0.125, 0.0625], VolumeByTag::default());
    let exporter = MetricsExporter::bind("127.0.0.1:0", Arc::clone(&sink)).unwrap();
    let out = Command::new(BSF_BIN)
        .args(["top", &exporter.addr().to_string(), "--once"])
        .output()
        .unwrap();
    assert!(out.status.success(), "{out:?}");
    let stdout = String::from_utf8(out.stdout).unwrap();
    assert!(stdout.contains("engine=threaded"), "{stdout}");
    assert!(stdout.contains("iteration=1"), "{stdout}");
    assert!(stdout.contains("send_order"), "{stdout}");
    assert!(stdout.contains("no worker heartbeats yet"), "{stdout}");
    exporter.shutdown();
}

/// An explicit `--events` value other than jsonl is a usage error
/// (exit 2), not a silent ignore.
#[test]
fn cli_rejects_unknown_events_format() {
    let out = Command::new(BSF_BIN)
        .args(["run", "jacobi", "--n", "16", "--events", "xml"])
        .output()
        .unwrap();
    assert_eq!(out.status.code(), Some(2), "{out:?}");
    let stderr = String::from_utf8(out.stderr).unwrap();
    assert!(stderr.contains("--events"), "{stderr}");
}
