//! Cross-module property tests (the repo's proptest substitute —
//! `bsf::util::qcheck`): the invariants that make the BSF skeleton
//! correct-by-construction.

use bsf::costmodel::{ClusterProfile, CostParams};
use bsf::problems::jacobi::JacobiProblem;
use bsf::problems::lpp::LppProblem;
use bsf::simcluster::SimConfig;
use bsf::skeleton::fault::redistribute;
use bsf::skeleton::reduce::{fold_extended, merge_folds};
use bsf::skeleton::split::all_ranges;
use bsf::skeleton::{Bsf, SimulatedEngine, ThreadedEngine};
use bsf::util::codec::Codec;
use bsf::util::qcheck::{qcheck, size_in};

#[test]
fn prop_redistributed_assignments_cover_exactly_once_in_order() {
    // Fault-recovery re-splitting: for arbitrary (K, loss set, list
    // length), the survivors' assignments cover the full list exactly
    // once (no gap, no overlap), merge order (logical rank) follows
    // survivor order, and the plan equals the canonical block split of
    // a fresh survivor-count run — the invariant that makes recovered
    // results identical to a fresh (K - losses)-worker run.
    qcheck(200, |rng| {
        let len = size_in(rng, 0, 400);
        let k = size_in(rng, 1, 24);
        let losses = size_in(rng, 0, k - 1);
        // Knock out `losses` distinct ranks deterministically from rng.
        let mut alive: Vec<usize> = (0..k).collect();
        for _ in 0..losses {
            let idx = size_in(rng, 0, alive.len() - 1);
            alive.remove(idx);
        }
        let plan = redistribute(len, &alive);
        assert_eq!(plan.len(), alive.len());
        let fresh = all_ranges(len, alive.len());
        let mut next = 0usize;
        for (i, a) in plan.iter().enumerate() {
            assert_eq!(a.logical, i, "merge order follows survivor order");
            assert_eq!(a.physical, alive[i], "ascending physical ranks");
            assert_eq!(a.offset, next, "no gap, no overlap");
            assert_eq!(
                (a.offset, a.length),
                fresh[i],
                "plan == canonical fresh split of the survivor count"
            );
            next = a.offset + a.length;
        }
        assert_eq!(next, len, "full coverage, exactly once");
    });
}

#[test]
fn prop_skeleton_result_is_k_invariant_jacobi() {
    // The skeleton's core contract: for associative exact ⊕ the result
    // does not depend on how the list is split over workers.
    qcheck(12, |rng| {
        let n = size_in(rng, 8, 40);
        let seed = rng.next();
        let k2 = size_in(rng, 2, 8);
        let (p1, _) = JacobiProblem::random(n, 1e-14, seed);
        let (p2, _) = JacobiProblem::random(n, 1e-14, seed);
        let r1 = Bsf::new(p1).workers(1).max_iter(500).run().unwrap();
        let r2 = Bsf::new(p2).workers(k2).max_iter(500).run().unwrap();
        assert_eq!(r1.iterations, r2.iterations);
        for (a, b) in r1.param.iter().zip(&r2.param) {
            assert!((a - b).abs() < 1e-8, "K-invariance broke: {a} vs {b}");
        }
    });
}

#[test]
fn prop_engines_numerics_agree() {
    // Threaded, serial (K=1) and simulated engines run the same math.
    qcheck(8, |rng| {
        let n = size_in(rng, 8, 32);
        let k = size_in(rng, 1, 6);
        let seed = rng.next();
        let (pt, _) = JacobiProblem::random(n, 1e-12, seed);
        let (ps, _) = JacobiProblem::random(n, 1e-12, seed);
        let rt = Bsf::new(pt)
            .workers(k)
            .max_iter(300)
            .engine(ThreadedEngine)
            .run()
            .unwrap();
        let rs = Bsf::new(ps)
            .workers(k)
            .max_iter(300)
            .engine(SimulatedEngine::new(ClusterProfile::gigabit()))
            .run()
            .unwrap();
        assert_eq!(rt.iterations, rs.iterations);
        for (a, b) in rt.param.iter().zip(&rs.param) {
            assert!((a - b).abs() < 1e-12);
        }
    });
}

#[test]
fn prop_overlapped_orders_are_bit_identical() {
    // The double-buffered order path (`cfg.overlap`) changes *when*
    // workers receive order i+1, never its bytes: order i+1 depends only
    // on reduce i, which the master has fully merged before pre-sending.
    // So for arbitrary (n, K, T) the overlapped run must be bit-identical
    // to the plain threaded run — same iteration count, bit-equal param,
    // and the same message count (overlap reorders sends, never adds
    // any) — for a dense Vec<f64> wire shape (jacobi) and a sparse
    // variable-length one (pagerank) alike.
    use bsf::problems::pagerank::PageRankProblem;
    use bsf::skeleton::BsfConfig;

    qcheck(6, |rng| {
        let n = size_in(rng, 8, 32);
        let k = size_in(rng, 1, 6);
        let t = size_in(rng, 1, 3);
        let seed = rng.next();
        let cfg = |overlap: bool| {
            BsfConfig::with_workers(k)
                .threads_per_worker(t)
                .max_iter(300)
                .overlapped(overlap)
        };

        let (p_off, _) = JacobiProblem::random(n, 1e-12, seed);
        let (p_on, _) = JacobiProblem::random(n, 1e-12, seed);
        let off = Bsf::new(p_off).config(cfg(false)).engine(ThreadedEngine).run().unwrap();
        let on = Bsf::new(p_on).config(cfg(true)).engine(ThreadedEngine).run().unwrap();
        assert_eq!(off.iterations, on.iterations);
        assert_eq!(off.param, on.param, "overlap must be bit-identical");
        assert_eq!(off.messages, on.messages, "overlap must not add messages");

        let mk = || PageRankProblem::new(n, n.clamp(1, 16), 1e-10, seed);
        let off = Bsf::new(mk()).config(cfg(false)).engine(ThreadedEngine).run().unwrap();
        let on = Bsf::new(mk()).config(cfg(true)).engine(ThreadedEngine).run().unwrap();
        assert_eq!(off.iterations, on.iterations);
        assert_eq!(off.param, on.param, "sparse payloads must be bit-identical too");
        assert_eq!(off.messages, on.messages);
    });
}

#[test]
fn prop_extended_reduce_counter_equals_participants() {
    qcheck(100, |rng| {
        let n = size_in(rng, 0, 80);
        let items: Vec<Option<u64>> = (0..n)
            .map(|_| if rng.f64() < 0.4 { None } else { Some(rng.below(100) as u64) })
            .collect();
        let participants = items.iter().filter(|i| i.is_some()).count() as u64;
        let fold = fold_extended(items.clone(), |a, b| a + b);
        assert_eq!(fold.counter, participants);
        let expect_sum: u64 = items.iter().flatten().sum();
        match fold.value {
            None => assert_eq!(participants, 0),
            Some(v) => assert_eq!(v, expect_sum),
        }
    });
}

#[test]
fn prop_merge_of_split_folds_equals_whole() {
    qcheck(100, |rng| {
        let n = size_in(rng, 1, 60);
        let k = size_in(rng, 1, 10);
        let items: Vec<Option<i64>> = (0..n)
            .map(|_| if rng.f64() < 0.3 { None } else { Some(rng.below(50) as i64 - 25) })
            .collect();
        let whole = fold_extended(items.clone(), |a, b| a + b);
        let parts = all_ranges(n, k);
        let merged = merge_folds(
            parts
                .iter()
                .map(|&(o, l)| fold_extended(items[o..o + l].iter().cloned(), |a, b| a + b)),
            |a, b| a + b,
        );
        assert_eq!(whole, merged);
    });
}

#[test]
fn prop_codec_roundtrip_fold_messages() {
    // The exact payload shape the master/worker exchange.
    qcheck(100, |rng| {
        let n = size_in(rng, 0, 30);
        let value: Option<Vec<f64>> = if rng.f64() < 0.2 {
            None
        } else {
            Some((0..n).map(|_| rng.normal()).collect())
        };
        let counter = rng.below(1000) as u64;
        let msg = (value.clone(), counter);
        let back = <(Option<Vec<f64>>, u64)>::from_bytes(&msg.to_bytes());
        assert_eq!(back, msg);
    });
}

#[test]
fn prop_codec_roundtrip_every_problem_payload_type() {
    // Every Param / ReduceElem the seven problems put on the wire
    // (thread channels *and* TCP frames) must round-trip losslessly:
    // jacobi/cimmino/lpp (Vec<f64>), apex ((Vec<f64>, f64) + ApexReduce),
    // jacobi-map (Vec<(u64, f64)>), gravity (Vec<(u64, [f64; 3])>),
    // montecarlo ((u64, u64)), lpp-validator ((f64, u64, u64) +
    // ViolationReport) — plus the order/fold envelopes around them.
    use bsf::problems::apex::ApexReduce;
    use bsf::problems::lpp_validator::ViolationReport;

    fn rt<T: Codec + PartialEq + std::fmt::Debug>(v: T) {
        assert_eq!(T::from_bytes(&v.to_bytes()), v);
    }

    qcheck(60, |rng| {
        let n = size_in(rng, 0, 16);
        let vecf: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        rt(vecf.clone());
        rt((vecf.clone(), rng.normal()));
        rt(ApexReduce::Corr(vecf.clone()));
        rt(ApexReduce::MinStep(rng.normal()));
        rt(ApexReduce::MaxViol(rng.normal()));
        rt((0..n).map(|i| (i as u64, rng.normal())).collect::<Vec<(u64, f64)>>());
        rt((0..n)
            .map(|i| (i as u64, [rng.normal(), rng.normal(), rng.normal()]))
            .collect::<Vec<(u64, [f64; 3])>>());
        rt((rng.next(), rng.next(), rng.next()));
        rt((rng.normal(), rng.next(), rng.next()));
        rt(ViolationReport { worst: rng.normal(), violated: rng.next(), active: rng.next() });
        // the order envelope (job, iter, param) and fold envelope
        // (value, counter)
        rt((size_in(rng, 0, 3), size_in(rng, 0, 99_999), vecf.clone()));
        rt((if rng.f64() < 0.2 { None } else { Some(vecf.clone()) }, rng.next()));
        // the worker's end-of-run report envelope
        rt((size_in(rng, 0, 9), size_in(rng, 0, 999), rng.normal(), size_in(rng, 0, 999)));
    });
}

#[test]
fn prop_codec_roundtrip_sparse_workload_payloads() {
    // The variable-length Param / ReduceElem shapes the sparse and ML
    // workloads put on the wire — pagerank's sparse (node, fixed-point
    // mass) rows, kmeans' per-centroid partial-sum rows, sgd's
    // (run_seed, weights) param and (gradient, batch-count) fold, and
    // montecarlo's 3-field tally. Nothing here is fixed-size, so the
    // length-prefixed Vec codec carries the structure end to end.
    fn rt<T: Codec + PartialEq + std::fmt::Debug>(v: T) {
        assert_eq!(T::from_bytes(&v.to_bytes()), v);
    }

    qcheck(60, |rng| {
        let n = size_in(rng, 0, 16);
        // montecarlo Param: (run_seed, hits, total)
        rt((rng.next(), rng.next(), rng.next()));
        // pagerank ReduceElem: sorted sparse (target, fixed-point mass)
        rt((0..n)
            .map(|i| (i as u32 * 3, rng.next() as i64))
            .collect::<Vec<(u32, i64)>>());
        // kmeans ReduceElem: one (sx, sy, sz, count) row per centroid
        rt((0..n)
            .map(|_| {
                (rng.next() as i64, rng.next() as i64, rng.next() as i64, rng.below(1000)
                    as u64)
            })
            .collect::<Vec<(i64, i64, i64, u64)>>());
        // sgd Param (run_seed, weights) and ReduceElem (grad, count)
        let w: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        rt((rng.next(), w));
        rt((
            (0..n + 1).map(|_| rng.next() as i64).collect::<Vec<i64>>(),
            rng.below(500) as u64,
        ));
        // ...and the fold envelope around a variable-size payload, the
        // shape the master actually receives per worker
        let sparse: Option<Vec<(u32, i64)>> = if rng.f64() < 0.2 {
            None
        } else {
            Some((0..n).map(|i| (i as u32, rng.next() as i64)).collect())
        };
        rt((sparse, rng.next()));
    });
}

#[test]
fn prop_tcp_frames_survive_partial_reads() {
    // The TCP transport's frame codec against a worst-case trickling
    // socket: frames (including empty payloads and arbitrary
    // Tag::User(u16) values) must decode exactly from 1–3-byte reads,
    // and truncation must be an error, never a garbage frame.
    use bsf::transport::tcp::{read_frame, write_frame};
    use bsf::transport::Tag;
    use std::io::Read;

    struct Trickle<'a> {
        data: &'a [u8],
        pos: usize,
        chunk: usize,
    }
    impl Read for Trickle<'_> {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            let n = buf.len().min(self.chunk).min(self.data.len() - self.pos);
            buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    qcheck(60, |rng| {
        let frames: Vec<(usize, Tag, Vec<u8>)> = (0..size_in(rng, 1, 5))
            .map(|_| {
                let tag = match rng.below(5) {
                    0 => Tag::Order,
                    1 => Tag::Fold,
                    2 => Tag::Exit,
                    3 => Tag::Abort,
                    _ => Tag::User(rng.next() as u16),
                };
                let len = if rng.f64() < 0.3 { 0 } else { size_in(rng, 1, 200) };
                let payload: Vec<u8> = (0..len).map(|_| rng.next() as u8).collect();
                (rng.below(9), tag, payload)
            })
            .collect();
        let mut buf = Vec::new();
        for (from, tag, payload) in &frames {
            write_frame(&mut buf, *from, *tag, payload).unwrap();
        }

        let chunk = size_in(rng, 1, 3);
        let mut r = Trickle { data: &buf, pos: 0, chunk };
        for (from, tag, payload) in &frames {
            let (f, t, p) = read_frame(&mut r).unwrap();
            assert_eq!((f, t, &p), (*from, *tag, payload));
        }
        let eof = read_frame(&mut r).unwrap_err();
        assert!(eof.to_string().contains("connection closed"), "{eof}");

        // a torn stream decodes only whole frames, then errors
        let cut = 1 + rng.below(buf.len() - 1);
        let mut r = Trickle { data: &buf[..cut], pos: 0, chunk };
        let mut whole = 0usize;
        while read_frame(&mut r).is_ok() {
            whole += 1;
        }
        assert!(whole < frames.len(), "cut at {cut}/{} lost no frame", buf.len());
    });
}

#[test]
fn prop_variable_wire_payloads_survive_partial_reads() {
    // Variable-length ReduceElem payloads (the pagerank/kmeans/sgd wire
    // shapes) framed back-to-back with *different* sizes per frame, read
    // off a worst-case trickling socket: each frame must cut exactly at
    // its length prefix and decode to the original value. This is the
    // failure mode fixed-size codecs never exercise — a frame boundary
    // landing inside another element's length prefix.
    use bsf::transport::tcp::{read_frame, write_frame};
    use bsf::transport::Tag;
    use std::io::Read;

    struct Trickle<'a> {
        data: &'a [u8],
        pos: usize,
        chunk: usize,
    }
    impl Read for Trickle<'_> {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            let n = buf.len().min(self.chunk).min(self.data.len() - self.pos);
            buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    type SparseFold = (Option<Vec<(u32, i64)>>, u64);

    qcheck(40, |rng| {
        let folds: Vec<SparseFold> = (0..size_in(rng, 1, 5))
            .map(|_| {
                let n = size_in(rng, 0, 40);
                (
                    if rng.f64() < 0.2 {
                        None
                    } else {
                        Some((0..n).map(|i| (i as u32, rng.next() as i64)).collect())
                    },
                    rng.next(),
                )
            })
            .collect();
        let mut buf = Vec::new();
        for (i, fold) in folds.iter().enumerate() {
            write_frame(&mut buf, i, Tag::Fold, &fold.to_bytes()).unwrap();
        }
        let chunk = size_in(rng, 1, 3);
        let mut r = Trickle { data: &buf, pos: 0, chunk };
        for (i, fold) in folds.iter().enumerate() {
            let (from, tag, payload) = read_frame(&mut r).unwrap();
            assert_eq!((from, tag), (i, Tag::Fold));
            assert_eq!(payload, fold.to_bytes(), "frame bytes shifted");
            assert_eq!(&SparseFold::from_bytes(&payload), fold);
        }
    });
}

#[test]
fn prop_checkpoint_codec_roundtrip_every_problem() {
    // A Checkpoint<P::Param> must cross the Codec losslessly for every
    // problem the CLI ships — the same wire the transport uses for the
    // order parameters, plus the checkpoint's magic/version header and
    // the (iter, job) counters the resume restores. The seeded variants
    // matter too: `bsf sweep` jobs start from seeded_parameter(seed)
    // through exactly this path.
    use bsf::problems::apex::ApexProblem;
    use bsf::problems::cimmino::CimminoProblem;
    use bsf::problems::gravity::GravityProblem;
    use bsf::problems::jacobi_map::JacobiMapProblem;
    use bsf::problems::kmeans::KMeansProblem;
    use bsf::problems::montecarlo::MonteCarloProblem;
    use bsf::problems::pagerank::PageRankProblem;
    use bsf::problems::sgd::SgdProblem;
    use bsf::skeleton::{BsfProblem, Checkpoint};

    fn rt<Param>(param: Param, iter: usize, job: usize)
    where
        Param: Codec + Clone + PartialEq + std::fmt::Debug,
    {
        let ck = Checkpoint { param, iter, job };
        let bytes = ck.to_bytes();
        assert_eq!(Checkpoint::<Param>::from_bytes(&bytes), ck);
        assert_eq!(Checkpoint::<Param>::try_from_bytes(&bytes).unwrap(), ck);
    }

    qcheck(12, |rng| {
        let n = size_in(rng, 2, 24);
        let seed = rng.next();
        let iter = rng.below(100_000);
        // A perturbed mid-run-looking parameter, not just the pristine
        // initial one.
        let perturb = |xs: Vec<f64>, rng: &mut bsf::util::rng::SplitMix64| -> Vec<f64> {
            xs.into_iter().map(|v| v + rng.normal()).collect()
        };

        let p = JacobiProblem::random(n, 1e-12, seed).0;
        rt(perturb(p.init_parameter(), rng), iter, 0);

        let p = JacobiMapProblem::random(n, 1e-12, seed).0;
        rt(perturb(p.init_parameter(), rng), iter, 0);

        let p = CimminoProblem::random(n, n, 1e-12, seed).0;
        rt(perturb(p.init_parameter(), rng), iter, 0);

        let p = GravityProblem::random(n, 1e-3, 5, seed);
        rt(perturb(p.init_parameter(), rng), iter, 0);

        let p = LppProblem::random(4 * n, n, seed);
        rt(perturb(p.init_parameter(), rng), iter, 0);

        // Montecarlo's tally param is exactly integral, and its run
        // seed rides in the first field.
        let p = MonteCarloProblem::new(n, 100, 1e-3);
        let _ = p.init_parameter();
        rt(p.seeded_parameter(rng.next()), iter, 0);
        rt((rng.next(), rng.next(), rng.next()), iter, 0);

        // The sparse/ML workloads: seeded starts are exactly what a
        // sweep job's iteration-0 checkpoint carries.
        let p = PageRankProblem::new(n, n.clamp(1, 4), 1e-12, seed);
        rt(p.seeded_parameter(rng.next()), iter, 0);

        let p = KMeansProblem::new(n.max(4), 2, 1e-12, seed);
        rt(p.seeded_parameter(rng.next()), iter, 0);

        let p = SgdProblem::new(n.max(4), 1e-12, seed);
        let (rs, w) = p.seeded_parameter(rng.next());
        rt((rs, perturb(w, rng)), iter, 0);

        // Apex is the multi-job workflow: the job case must survive too.
        let p = ApexProblem::random(4 * n, n, seed);
        let job = rng.below(p.job_count());
        let (xs, aux) = p.init_parameter();
        rt((perturb(xs, rng), aux + rng.normal()), iter, job);
    });
}

#[test]
fn prop_cost_model_t1_consistency_and_positive() {
    qcheck(200, |rng| {
        let p = CostParams {
            latency: rng.range(0.0, 1e-4),
            t_send: rng.range(0.0, 1e-3),
            t_recv: rng.range(0.0, 1e-3),
            t_map: rng.range(1e-6, 1.0),
            t_red: rng.range(0.0, 1e-2),
            t_op: rng.range(0.0, 1e-5),
            t_proc: rng.range(0.0, 1e-3),
        };
        for k in [1usize, 2, 7, 33, 512] {
            assert!(p.iteration_time(k) > 0.0);
        }
        // T(1) == the sum of all serial parts
        let t1 = 2.0 * p.latency + p.t_send + p.t_recv + p.t_map + p.t_red + p.t_proc;
        assert!((p.iteration_time(1) - t1).abs() < 1e-12);
        // the analytic boundary is a stationary point of T
        let km = p.k_max();
        if km.is_finite() && km >= 2.0 {
            let k = km.round() as usize;
            assert!(p.iteration_time(k) <= p.iteration_time(k * 4) + 1e-12);
            assert!(p.iteration_time(k) <= p.iteration_time(1.max(k / 4)) + 1e-12);
        }
    });
}

#[test]
fn prop_lpp_feasibility_reached_for_random_polytopes() {
    qcheck(10, |rng| {
        let m = size_in(rng, 12, 60);
        let n = size_in(rng, 2, 8);
        let p = LppProblem::random(m, n, rng.next());
        let p = std::sync::Arc::new(p);
        let r = Bsf::from_arc(std::sync::Arc::clone(&p))
            .workers(size_in(rng, 1, 6))
            .max_iter(100_000)
            .run()
            .unwrap();
        assert_eq!(p.violations(&r.param), 0, "infeasible after {}", r.iterations);
    });
}

#[test]
fn prop_sim_virtual_time_monotone_in_latency() {
    qcheck(8, |rng| {
        let n = size_in(rng, 12, 32);
        let k = size_in(rng, 2, 8);
        let seed = rng.next();
        let vt = |latency: f64| {
            let (p, _) = JacobiProblem::random(n, 1e-30, seed);
            let sim = SimConfig::new(ClusterProfile { latency, byte_time: 1e-9 })
                .per_element(1e-6);
            let r = Bsf::new(p)
                .workers(k)
                .max_iter(5)
                .engine(SimulatedEngine::with_config(sim))
                .run()
                .unwrap();
            r.elapsed
        };
        let a = vt(1e-6);
        let b = vt(1e-3);
        assert!(b > a, "higher latency must cost virtual time: {a} vs {b}");
    });
}

#[test]
fn prop_transport_byte_accounting_matches_payloads() {
    use bsf::transport::{build_thread_transport, Communicator, Tag};
    qcheck(30, |rng| {
        let k = size_in(rng, 1, 5);
        let mut eps = build_thread_transport(k);
        let master = eps.pop().unwrap();
        let mut total = 0u64;
        let sizes: Vec<usize> = (0..k).map(|_| rng.below(2000)).collect();
        let handles: Vec<_> = eps
            .into_iter()
            .zip(sizes.clone())
            .map(|(w, sz)| {
                std::thread::spawn(move || {
                    w.send(w.master_rank(), Tag::Fold, vec![7u8; sz]).unwrap();
                })
            })
            .collect();
        for _ in 0..k {
            total += master.recv_any(Tag::Fold).unwrap().payload.len() as u64;
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(total, sizes.iter().sum::<usize>() as u64);
        assert_eq!(master.stats().byte_count(), total);
        assert_eq!(master.stats().message_count(), k as u64);
    });
}

#[test]
fn prop_montecarlo_tally_k_invariant() {
    use bsf::problems::montecarlo::MonteCarloProblem;
    qcheck(6, |rng| {
        let blocks = size_in(rng, 2, 20);
        let mk = || {
            let mut p = MonteCarloProblem::new(blocks, 200, 1e-12);
            p.max_rounds = 2;
            p
        };
        let k1 = Bsf::new(mk()).workers(1).run().unwrap();
        let kn = Bsf::new(mk()).workers(size_in(rng, 2, 6)).run().unwrap();
        assert_eq!(k1.param, kn.param, "tallies must not depend on K");
    });
}
