//! Integration: every demo application solved end-to-end on the skeleton
//! through the session API, plus cross-problem consistency and the cost
//! model's ordering claims.

use std::sync::Arc;

use bsf::costmodel::{calibrate, ClusterProfile};
use bsf::problems::cimmino::CimminoProblem;
use bsf::problems::gravity::GravityProblem;
use bsf::problems::jacobi::JacobiProblem;
use bsf::problems::jacobi_map::JacobiMapProblem;
use bsf::problems::lpp::LppProblem;
use bsf::problems::montecarlo::MonteCarloProblem;
use bsf::skeleton::Bsf;
use bsf::util::mat::dist2;

#[test]
fn cimmino_solves_consistent_system() {
    let (p, _x_star) = CimminoProblem::random(96, 24, 1e-16, 201);
    let p = Arc::new(p);
    let r = Bsf::from_arc(Arc::clone(&p))
        .workers(6)
        .max_iter(50_000)
        .run()
        .unwrap();
    // projection methods converge slowly; require a strong residual drop
    let r0 = p.residual2(&vec![0.0; 24]);
    assert!(p.residual2(&r.param) < r0 * 1e-8);
}

#[test]
fn jacobi_and_jacobi_map_same_fixed_point() {
    let (pa, x_star) = JacobiProblem::random(48, 1e-22, 202);
    let (pb, _) = JacobiMapProblem::random(48, 1e-22, 202);
    let ra = Bsf::new(pa).workers(4).run().unwrap();
    let rb = Bsf::new(pb).workers(4).run().unwrap();
    assert!(dist2(&ra.param, &x_star) < 1e-10);
    assert!(dist2(&rb.param, &x_star) < 1e-10);
}

#[test]
fn gravity_deterministic_and_step_counted() {
    let p = GravityProblem::random(24, 5e-4, 40, 203);
    let r = Bsf::new(p).workers(5).run().unwrap();
    assert_eq!(r.iterations, 40);
    assert!(r.param.iter().all(|v| v.is_finite()));
}

#[test]
fn montecarlo_reaches_tolerance() {
    let p = MonteCarloProblem::new(8, 5_000, 4e-3);
    let r = Bsf::new(p).workers(4).run().unwrap();
    assert!(MonteCarloProblem::stderr(&r.param) < 4e-3);
    let pi = MonteCarloProblem::estimate(&r.param);
    assert!((pi - std::f64::consts::PI).abs() < 0.05);
}

#[test]
fn lpp_extended_reduce_drives_stop() {
    let p = LppProblem::random(80, 10, 204);
    let p = Arc::new(p);
    let r = Bsf::from_arc(Arc::clone(&p))
        .workers(8)
        .max_iter(50_000)
        .run()
        .unwrap();
    assert_eq!(p.violations(&r.param), 0);
    // the run stopped because the final counter was 0, not max_iter
    assert!(r.iterations < 50_000);
}

#[test]
fn boundary_ordering_gravity_beats_jacobi_beats_montecarlo_comm_ratio() {
    // The cost model's cross-problem claim: compute-heavy gravity has a
    // later scalability boundary than Jacobi at the same n; Monte-Carlo
    // (tiny messages) later still.
    let profile = ClusterProfile::gigabit();
    let (jac, _) = JacobiProblem::random(192, 1e-30, 205);
    let grav = GravityProblem::random(192, 1e-3, 5, 205);
    let k_jac = calibrate(&jac, profile, 3).params.k_max();
    let k_grav = calibrate(&grav, profile, 3).params.k_max();
    assert!(
        k_grav > k_jac,
        "gravity boundary {k_grav} should exceed jacobi {k_jac}"
    );
}

#[test]
fn calibration_t_map_scales_with_n() {
    let profile = ClusterProfile::infiniband();
    let (p1, _) = JacobiProblem::random(64, 1e-30, 206);
    let (p2, _) = JacobiProblem::random(256, 1e-30, 206);
    let c1 = calibrate(&p1, profile, 3);
    let c2 = calibrate(&p2, profile, 3);
    // t_map is Θ(n²): 4x n → ~16x t_map. Allow wide noise margins.
    let ratio = c2.params.t_map / c1.params.t_map;
    assert!(ratio > 4.0, "t_map ratio {ratio} too small for Θ(n²)");
}

#[test]
fn k_max_grows_with_problem_size_sqrt_law() {
    // The paper's signature: K_max = Θ(√n) for Jacobi.
    let profile = ClusterProfile::gigabit();
    let (p1, _) = JacobiProblem::random(128, 1e-30, 207);
    let (p2, _) = JacobiProblem::random(512, 1e-30, 207);
    let k1 = calibrate(&p1, profile, 3).params.k_max();
    let k2 = calibrate(&p2, profile, 3).params.k_max();
    // n×4 with Θ(n²) map and Θ(n) comm ⇒ K_max ×~2 (√ law); very loose
    // bounds to stay robust on noisy CI machines.
    let growth = k2 / k1;
    assert!(
        growth > 1.2 && growth < 5.0,
        "K_max growth {growth} outside √-law range (k1={k1}, k2={k2})"
    );
}
