//! Distributed-mode integration: the skeleton across **real OS
//! processes** over TCP. These tests spawn the actual `bsf` binary
//! (`CARGO_BIN_EXE_bsf`) as worker processes, so a passing run here is
//! master + K workers = K+1 live processes on this machine — the
//! acceptance shape of the paper's `BC_MpiRun` launch model.

use std::net::TcpListener;
use std::process::{Command, Stdio};
use std::time::Duration;

use bsf::problems::jacobi::JacobiProblem;
use bsf::skeleton::BsfProblem;
use bsf::transport::tcp::{accept_workers, ProblemSig};
use bsf::transport::{Communicator, Tag};
use bsf::util::codec::Codec;
use bsf::{Bsf, BsfError, ProcessEngine, ThreadedEngine};

const BSF_BIN: &str = env!("CARGO_BIN_EXE_bsf");

fn jacobi_worker_argv(n: usize) -> Vec<String> {
    ["worker", "--problem", "jacobi", "--n"]
        .iter()
        .map(|s| s.to_string())
        .chain([n.to_string()])
        .chain(["--seed", "7", "--eps", "1e-12"].iter().map(|s| s.to_string()))
        .collect()
}

#[test]
fn process_engine_matches_threaded_across_real_processes() {
    let n = 48;
    let (pt, _) = JacobiProblem::random(n, 1e-12, 7);
    let rt = Bsf::new(pt).workers(2).engine(ThreadedEngine).run().unwrap();

    let (pp, _) = JacobiProblem::random(n, 1e-12, 7);
    let engine = ProcessEngine::spawn_args(jacobi_worker_argv(n)).program(BSF_BIN);
    let rp = Bsf::new(pp).workers(2).engine(engine).run().unwrap();

    assert_eq!(rp.engine, "process");
    assert_eq!(rp.iterations, rt.iterations, "same stop condition, same count");
    assert_eq!(rp.param, rt.param, "rank-ordered fold must be bit-identical");

    // Per-worker summaries crossed the process boundary intact.
    assert_eq!(rp.workers.len(), 2);
    assert_eq!(rp.workers[0].rank, 0);
    assert_eq!(rp.workers[1].rank, 1);
    assert_eq!(rp.workers[0].sublist_length + rp.workers[1].sublist_length, n);
    assert!(rp.workers.iter().all(|w| w.iterations == rp.iterations));

    // Per-tag accounting at the master endpoint: K orders + K folds + K
    // exit flags per iteration, plus one end-of-run report per worker.
    let iters = rp.iterations as u64;
    assert_eq!(rp.volume.order.messages, 2 * iters);
    assert_eq!(rp.volume.fold.messages, 2 * iters);
    assert_eq!(rp.volume.exit.messages, 2 * iters);
    assert_eq!(rp.volume.user.messages, 2);
    assert_eq!(rp.volume.total_messages(), rp.messages);
    assert_eq!(rp.volume.total_bytes(), rp.bytes);
    assert!(rp.volume.order.bytes > 0 && rp.volume.fold.bytes > 0);
}

#[test]
fn listen_mode_accepts_prestarted_worker_processes() {
    // Reserve a port, then hand it to ProcessEngine::listen. Workers are
    // started *before* the master binds — their connect retry loop must
    // absorb that (the two-terminal start order).
    let addr = {
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        probe.local_addr().unwrap().to_string()
    };
    let n = 32;
    let mut children: Vec<_> = (0..2)
        .map(|rank: usize| {
            let mut argv = jacobi_worker_argv(n);
            argv.extend(["--connect".into(), addr.clone(), "--rank".into(), rank.to_string()]);
            Command::new(BSF_BIN)
                .args(&argv)
                .stdin(Stdio::null())
                .spawn()
                .unwrap()
        })
        .collect();

    let (p, _) = JacobiProblem::random(n, 1e-12, 7);
    let report = Bsf::new(p)
        .workers(2)
        .engine(ProcessEngine::listen(addr))
        .run()
        .unwrap();
    assert_eq!(report.engine, "process");
    assert!(report.iterations > 0);

    for child in &mut children {
        let status = child.wait().unwrap();
        assert!(status.success(), "pre-started worker exited with {status}");
    }
}

#[test]
fn killed_worker_process_yields_typed_error_not_a_hang() {
    let n = 32;
    let (p, _) = JacobiProblem::random(n, 1e-12, 7);
    let sig = ProblemSig {
        list_size: p.list_size() as u64,
        job_count: p.job_count() as u64,
    };
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let mut argv = jacobi_worker_argv(n);
    argv.extend(["--connect".into(), addr.clone(), "--rank".into(), "0".into()]);
    let mut child = Command::new(BSF_BIN)
        .args(&argv)
        .stdin(Stdio::null())
        .spawn()
        .unwrap();

    let master = accept_workers(listener, 1, sig, Duration::from_secs(30), || Ok(())).unwrap();

    // Drive one order → fold exchange by hand, so the kill lands at a
    // deterministic point: the worker blocked waiting for the exit flag.
    // Envelope: (job, iterations-completed, param).
    let order = (0usize, 0usize, p.init_parameter()).to_bytes();
    master.send(0, Tag::Order, order).unwrap();
    let fold = master.recv(0, Tag::Fold).unwrap();
    assert!(!fold.payload.is_empty());

    child.kill().unwrap();
    child.wait().unwrap();

    // The gather for the next iteration must surface the typed per-rank
    // loss (EOF from the dead worker), never block forever.
    let err = master.recv(0, Tag::Fold).unwrap_err();
    assert!(matches!(err, BsfError::WorkerLost { rank: 0, .. }), "{err}");
    let err = master.recv_any(Tag::Fold).unwrap_err();
    assert!(matches!(err, BsfError::WorkerLost { rank: 0, .. }), "{err}");
}

#[test]
fn cli_run_engine_process_matches_threaded_output() {
    // (stdout, stderr): results stay on stdout, diagnostics (traffic:)
    // on stderr.
    let run = |engine: &str| {
        let out = Command::new(BSF_BIN)
            .args(["run", "jacobi", "--n", "64", "--engine", engine, "--workers", "2"])
            .output()
            .unwrap();
        assert!(
            out.status.success(),
            "bsf run --engine {engine} failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        (
            String::from_utf8_lossy(&out.stdout).to_string(),
            String::from_utf8_lossy(&out.stderr).to_string(),
        )
    };
    let (process, process_err) = run("process");
    let (threaded, _) = run("threaded");
    assert!(process.contains("engine=process"), "{process}");

    let line = |s: &str, prefix: &str| {
        s.lines().find(|l| l.starts_with(prefix)).map(str::to_string)
    };
    assert_eq!(line(&process, "result:"), line(&threaded, "result:"));
    let iterations = |s: &str| {
        s.split_whitespace()
            .find_map(|w| w.strip_prefix("iterations=").map(str::to_string))
    };
    assert_eq!(iterations(&process), iterations(&threaded));
    assert!(process_err.contains("traffic: order="), "{process_err}");
}
