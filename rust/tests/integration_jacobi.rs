//! Integration: the full skeleton solving Jacobi end-to-end, across
//! worker counts, engines, backends and OpenMP settings — all through
//! the `Bsf` session API.

use bsf::costmodel::ClusterProfile;
use bsf::problems::jacobi::JacobiProblem;
use bsf::simcluster::SimConfig;
use bsf::skeleton::{
    Bsf, BsfConfig, PerElementBackend, SimulatedEngine, ThreadedEngine,
};
use bsf::util::mat::dist2;

#[test]
fn threaded_solution_matches_truth_many_ks() {
    for k in [1usize, 2, 3, 7, 16] {
        let (p, x_star) = JacobiProblem::random(64, 1e-22, 100 + k as u64);
        // force the threaded engine even at K=1
        let r = Bsf::new(p).workers(k).engine(ThreadedEngine).run().unwrap();
        assert!(
            dist2(&r.param, &x_star) < 1e-10,
            "K={k}: dist² {}",
            dist2(&r.param, &x_star)
        );
    }
}

#[test]
fn message_count_matches_algorithm_2() {
    // Per iteration: K orders + K folds + K exits = 3K messages.
    let k = 5;
    let (p, _) = JacobiProblem::random(32, 1e-16, 3);
    let r = Bsf::new(p).workers(k).run().unwrap();
    assert_eq!(r.messages, (3 * k * r.iterations) as u64);
}

#[test]
fn simulated_cluster_same_numerics_as_threaded() {
    let (pt, _) = JacobiProblem::random(48, 1e-18, 4);
    let (ps, _) = JacobiProblem::random(48, 1e-18, 4);
    let rt = Bsf::new(pt).workers(6).run().unwrap();
    let rs = Bsf::new(ps)
        .workers(6)
        .engine(SimulatedEngine::new(ClusterProfile::infiniband()))
        .run()
        .unwrap();
    assert_eq!(rt.iterations, rs.iterations);
    for (a, b) in rt.param.iter().zip(&rs.param) {
        assert!((a - b).abs() < 1e-12);
    }
}

#[test]
fn simulated_virtual_time_has_scalability_peak_shape() {
    // With a slow interconnect and a small problem, K=64 must be slower
    // per iteration than the best small K — the boundary exists.
    let profile = ClusterProfile::gigabit();
    let per_iter = |k: usize| {
        let (p, _) = JacobiProblem::random(96, 1e-30, 5);
        // 50µs/elem ⇒ t_map = 4.8ms ≫ per-message cost (~56µs), so a
        // boundary exists between K=4 and K=96.
        let r = Bsf::new(p)
            .config(BsfConfig::with_workers(k).max_iter(8))
            .engine(SimulatedEngine::with_config(
                SimConfig::new(profile).per_element(50e-6),
            ))
            .run()
            .unwrap();
        r.elapsed / r.iterations as f64
    };
    let t1 = per_iter(1);
    let t4 = per_iter(4);
    let t96 = per_iter(96);
    assert!(t4 < t1, "t4 {t4} should beat t1 {t1}");
    assert!(t96 > t4, "t96 {t96} should be past the boundary vs t4 {t4}");
}

#[test]
fn openmp_and_plain_agree_at_scale() {
    let (p1, _) = JacobiProblem::random(128, 1e-16, 6);
    let (p2, _) = JacobiProblem::random(128, 1e-16, 6);
    let r1 = Bsf::new(p1).workers(2).run().unwrap();
    let r2 = Bsf::new(p2)
        .config(BsfConfig::with_workers(2).threads_per_worker(4))
        .run()
        .unwrap();
    assert_eq!(r1.iterations, r2.iterations);
    for (a, b) in r1.param.iter().zip(&r2.param) {
        assert!((a - b).abs() < 1e-9);
    }
}

#[test]
fn per_element_backend_matches_fused() {
    let (p1, _) = JacobiProblem::random(40, 1e-18, 7);
    let (p2, _) = JacobiProblem::random(40, 1e-18, 7);
    let r1 = Bsf::new(p1)
        .workers(4)
        .map_backend(PerElementBackend)
        .run()
        .unwrap();
    let r2 = Bsf::new(p2).workers(4).run().unwrap();
    assert_eq!(r1.iterations, r2.iterations);
    for (a, b) in r1.param.iter().zip(&r2.param) {
        assert!((a - b).abs() < 1e-9);
    }
}

#[test]
fn trace_output_does_not_change_results() {
    let (p1, _) = JacobiProblem::random(32, 1e-16, 8);
    let (p2, _) = JacobiProblem::random(32, 1e-16, 8);
    let r1 = Bsf::new(p1).workers(3).run().unwrap();
    let r2 = Bsf::new(p2).workers(3).trace(2).run().unwrap();
    assert_eq!(r1.iterations, r2.iterations);
    assert_eq!(r1.param, r2.param);
}

#[test]
fn max_iter_caps_divergence_guard() {
    let (p, _) = JacobiProblem::random(32, 1e-300, 9); // unreachable eps
    let r = Bsf::new(p).workers(2).max_iter(17).run().unwrap();
    assert_eq!(r.iterations, 17);
}

#[test]
fn more_workers_than_list_elements() {
    // The paper says list size *should* be >= K, but the skeleton must
    // still function: surplus workers hold empty sublists and contribute
    // empty folds (counter 0) that the extended reduce skips.
    let (p, x_star) = JacobiProblem::random(6, 1e-20, 10);
    let r = Bsf::new(p).workers(9).run().unwrap();
    assert!(dist2(&r.param, &x_star) < 1e-10);
}

#[test]
fn single_element_list() {
    // n=1: C = [0], d = b/a, converges in one step.
    let (p, x_star) = JacobiProblem::random(1, 1e-20, 11);
    let r = Bsf::new(p).workers(1).run().unwrap();
    assert!((r.param[0] - x_star[0]).abs() < 1e-10);
    assert!(r.iterations <= 3);
}

#[test]
fn run_threaded_session_matches_the_session_api() {
    // The library-level convenience (what the seed-era `run_threaded`
    // shim wrapped before its deletion) is the same code path the
    // session API drives — typed errors included.
    let r = bsf::skeleton::runner::run_threaded_session(
        std::sync::Arc::new(JacobiProblem::random(24, 1e-18, 12).0),
        std::sync::Arc::new(bsf::FusedNativeBackend),
        &BsfConfig::with_workers(3),
    )
    .unwrap();
    let (p2, _) = JacobiProblem::random(24, 1e-18, 12);
    let r2 = Bsf::new(p2).workers(3).run().unwrap();
    assert_eq!(r.iterations, r2.iterations);
    assert_eq!(r.param, r2.param);
}
