//! Integration: the multi-tenant scheduler over one persistent
//! OS-process fleet (`bsf serve`'s machinery, driven in-process) —
//! concurrent jobs split the fleet, results stay bit-identical to solo
//! runs, worker pids prove process reuse across jobs, and the HTTP
//! control plane round-trips submissions end to end.

use std::collections::BTreeSet;
use std::sync::Arc;
use std::time::{Duration, Instant};

use bsf::metrics::control::ControlServer;
use bsf::metrics::exporter::{http_get, http_post};
use bsf::metrics::telemetry::RunTelemetry;
use bsf::problems::jacobi::JacobiProblem;
use bsf::skeleton::{Cluster, ControlApi, JobContract, JobStatus, Scheduler};
use bsf::util::json::Json;
use bsf::{Bsf, BsfConfig, ThreadedEngine};

const BSF_BIN: &str = env!("CARGO_BIN_EXE_bsf");
const N: usize = 24;

fn worker_argv() -> Vec<String> {
    [
        "worker", "--problem", "jacobi", "--n", &N.to_string(), "--seed", "7",
        "--eps", "1e-12",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect()
}

fn jacobi() -> JacobiProblem {
    JacobiProblem::random(N, 1e-12, 7).0
}

/// What a solo `bsf run --workers K` of the same instance produces (all
/// engines are bit-identical at equal K, so the threaded engine is a
/// valid stand-in for a K-worker cluster run).
fn solo_reference(k: usize) -> (String, usize) {
    let r = Bsf::new(jacobi()).workers(k).engine(ThreadedEngine).run().unwrap();
    (format!("{:?}", r.param), r.iterations)
}

#[test]
fn concurrent_jobs_split_a_process_fleet_bit_identically() {
    let cluster = Cluster::spawn(4, worker_argv())
        .program(BSF_BIN)
        .start(&jacobi())
        .unwrap();
    let sched = Arc::new(
        Scheduler::new(
            cluster.pool(),
            Arc::new(jacobi()),
            "jacobi",
            BsfConfig::with_workers(4),
        )
        .describe_with(|x| format!("{x:?}")),
    );

    // Queue two half-fleet jobs while paused so they dispatch together.
    sched.pause();
    let a = sched.submit(JobContract { workers: 2, ..Default::default() }).unwrap();
    let b = sched.submit(JobContract { workers: 2, ..Default::default() }).unwrap();
    sched.resume();
    assert!(sched.wait_idle(Duration::from_secs(120)), "jobs must finish");

    let (want, want_iters) = solo_reference(2);
    let ja = sched.job(a).unwrap();
    let jb = sched.job(b).unwrap();
    let mut pids = BTreeSet::new();
    for j in [&ja, &jb] {
        assert_eq!(j.status, JobStatus::Done, "{:?}", j.error);
        assert_eq!(j.iterations, want_iters, "scheduled == solo iteration count");
        assert_eq!(j.result.as_deref(), Some(want.as_str()), "bit-identical result");
        assert_eq!(j.granted.len(), 2);
        assert_eq!(j.pids.len(), 2);
        for &pid in &j.pids {
            assert_ne!(pid, 0);
            assert_ne!(pid, std::process::id() as u64, "real worker processes");
            pids.insert(pid);
        }
    }
    // Disjoint halves of one fleet: 4 distinct ranks, 4 distinct pids.
    let ranks: BTreeSet<usize> = ja.granted.iter().chain(&jb.granted).copied().collect();
    assert_eq!(ranks, (0..4).collect::<BTreeSet<_>>());
    assert_eq!(pids.len(), 4, "two jobs ran on four distinct worker processes");

    // Round two reuses the same OS processes — the amortization (and
    // multi-tenancy) witness: one fleet, many jobs, zero respawns.
    let (want4, want4_iters) = solo_reference(4);
    let c = sched.submit(JobContract { workers: 4, ..Default::default() }).unwrap();
    assert!(sched.wait_idle(Duration::from_secs(120)));
    let jc = sched.job(c).unwrap();
    assert_eq!(jc.status, JobStatus::Done, "{:?}", jc.error);
    assert_eq!(jc.iterations, want4_iters);
    assert_eq!(jc.result.as_deref(), Some(want4.as_str()));
    let again: BTreeSet<u64> = jc.pids.iter().copied().collect();
    assert_eq!(again, pids, "the second round must reuse the same worker processes");

    assert!(sched.request_shutdown(), "idle after drain");
    cluster.shutdown().unwrap();
}

#[test]
fn control_endpoint_drives_a_real_fleet_end_to_end() {
    const T: Duration = Duration::from_secs(5);
    let cluster = Cluster::spawn(2, worker_argv())
        .program(BSF_BIN)
        .start(&jacobi())
        .unwrap();
    let sink = Arc::new(RunTelemetry::new());
    let sched = Arc::new(
        Scheduler::new(
            cluster.pool(),
            Arc::new(jacobi()),
            "jacobi",
            BsfConfig::with_workers(2),
        )
        .describe_with(|x| format!("{x:?}"))
        .telemetry(Arc::clone(&sink)),
    );
    let server = ControlServer::bind(
        "127.0.0.1:0",
        Arc::new(Arc::clone(&sched)) as Arc<dyn ControlApi>,
    )
    .unwrap();
    let addr = server.addr().to_string();

    // A submission for the wrong problem is rejected with the server's
    // error text (one fleet serves one problem).
    let err = http_post(&addr, "/jobs", "{\"problem\": \"lpp\"}", T).unwrap_err();
    assert!(err.to_string().contains("jacobi"), "{err}");

    // `workers: "auto"` with no cost model takes the whole free fleet.
    let resp = http_post(
        &addr,
        "/jobs",
        "{\"problem\": \"jacobi\", \"workers\": \"auto\"}",
        T,
    )
    .unwrap();
    let id = Json::parse(&resp).unwrap().get("id").and_then(Json::as_u64).unwrap();

    // Poll GET /jobs until the job is terminal — exactly what
    // `bsf submit --wait` does.
    let deadline = Instant::now() + Duration::from_secs(120);
    let (status, result, iterations) = loop {
        assert!(Instant::now() < deadline, "job did not finish in time");
        let body = http_get(&addr, "/jobs", T).unwrap();
        let doc = Json::parse(&body).unwrap();
        let rows = doc.get("jobs").and_then(Json::as_arr).expect("jobs array");
        let row = rows
            .iter()
            .find(|r| r.get("id").and_then(Json::as_u64) == Some(id))
            .expect("submitted job row");
        let status = row.get("status").and_then(Json::as_str).unwrap().to_string();
        if status == "queued" || status == "running" {
            std::thread::sleep(Duration::from_millis(100));
            continue;
        }
        break (
            status,
            row.get("result").and_then(Json::as_str).map(str::to_string),
            row.get("iterations").and_then(Json::as_u64).unwrap_or(0) as usize,
        );
    };
    let (want, want_iters) = solo_reference(2);
    assert_eq!(status, "done");
    assert_eq!(result.as_deref(), Some(want.as_str()), "HTTP result == solo result");
    assert_eq!(iterations, want_iters);

    // The metrics document grew the additive scheduler keys the CI
    // smoke job curls for, and the job lifecycle is on the event stream.
    let m = Json::parse(&http_get(&addr, "/metrics", T).unwrap()).unwrap();
    assert!(m.get("queue_depth").is_some(), "metrics carry queue_depth");
    assert_eq!(m.get("jobs").and_then(Json::as_arr).map(|j| j.len()), Some(1));
    let events = http_get(&addr, "/events", T).unwrap();
    assert!(events.contains("job_submitted"), "{events}");
    assert!(events.contains("job_started"), "{events}");
    assert!(events.contains("job_ended"), "{events}");

    // Drain over HTTP: no further submissions, then tear down.
    let resp = http_post(&addr, "/shutdown", "", T).unwrap();
    assert!(resp.contains("idle") || resp.contains("draining"), "{resp}");
    let err = http_post(&addr, "/jobs", "{\"problem\": \"jacobi\"}", T).unwrap_err();
    assert!(err.to_string().contains("draining"), "{err}");
    assert!(sched.wait_idle(Duration::from_secs(10)));
    server.shutdown();
    cluster.shutdown().unwrap();
}
