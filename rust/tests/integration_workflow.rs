//! Integration: workflow (multi-job) support through the Apex problem and
//! a purpose-built 2-job toy that checks job plumbing exactly.

use std::sync::Arc;

use bsf::problems::apex::{ApexProblem, ApexReduce, JOB_FEASIBILITY, JOB_PURSUIT, JOB_VERIFY};
use bsf::skeleton::problem::{BsfProblem, IterCtx, MapCtx};
use bsf::skeleton::{run_threaded, BsfConfig, StepDecision};
use bsf::util::codec::Codec;

/// Toy 2-job workflow: job 0 sums elements, job 1 counts them; the
/// dispatcher alternates jobs and exits after 6 iterations. Verifies the
/// job number travels to workers and the per-job reduce dispatch works.
struct TwoJob {
    n: usize,
}

impl BsfProblem for TwoJob {
    type Param = Vec<f64>; // [iterations_done, sum_acc, count_acc]
    type MapElem = usize;
    type ReduceElem = (u64, f64);

    fn list_size(&self) -> usize {
        self.n
    }

    fn map_list_elem(&self, i: usize) -> usize {
        i
    }

    fn init_parameter(&self) -> Vec<f64> {
        vec![0.0, 0.0, 0.0]
    }

    fn job_count(&self) -> usize {
        2
    }

    fn map_f(&self, &i: &usize, _param: &Vec<f64>, ctx: &MapCtx) -> Option<(u64, f64)> {
        match ctx.job_case {
            0 => Some((0, i as f64)),  // sum job
            1 => Some((1, 1.0)),       // count job
            j => panic!("job {j}"),
        }
    }

    fn reduce_f(&self, x: &(u64, f64), y: &(u64, f64), job: usize) -> (u64, f64) {
        assert_eq!(x.0 as usize, job, "payload tagged with wrong job");
        assert_eq!(y.0 as usize, job);
        (x.0, x.1 + y.1)
    }

    fn process_results(
        &self,
        reduce_result: Option<&(u64, f64)>,
        reduce_counter: u64,
        param: &mut Vec<f64>,
        ctx: &IterCtx,
    ) -> StepDecision {
        let (tag, val) = reduce_result.copied().unwrap();
        assert_eq!(reduce_counter as usize, self.n);
        assert_eq!(tag as usize, ctx.job_case);
        param[0] += 1.0;
        if ctx.job_case == 0 {
            param[1] = val;
            StepDecision::goto(1)
        } else {
            param[2] = val;
            StepDecision::goto(0)
        }
    }

    fn job_dispatcher(
        &self,
        param: &mut Vec<f64>,
        decision: StepDecision,
        _ctx: &IterCtx,
    ) -> Option<StepDecision> {
        if param[0] >= 6.0 && !decision.exit {
            Some(StepDecision::exit())
        } else {
            None
        }
    }
}

#[test]
fn two_job_workflow_alternates_and_dispatcher_exits() {
    let n = 10;
    let r = run_threaded(Arc::new(TwoJob { n }), &BsfConfig::with_workers(3));
    assert_eq!(r.iterations, 6);
    assert_eq!(r.param[1], (0..n).sum::<usize>() as f64); // sum job result
    assert_eq!(r.param[2], n as f64); // count job result
}

#[test]
fn two_job_result_independent_of_workers() {
    let r1 = run_threaded(Arc::new(TwoJob { n: 12 }), &BsfConfig::with_workers(1));
    let r4 = run_threaded(Arc::new(TwoJob { n: 12 }), &BsfConfig::with_workers(4));
    assert_eq!(r1.param, r4.param);
    assert_eq!(r1.iterations, r4.iterations);
}

#[test]
fn apex_three_jobs_run_and_converge() {
    let p = ApexProblem::random(32, 5, 301);
    let p = Arc::new(p);
    let r = run_threaded(Arc::clone(&p), &BsfConfig::with_workers(4).max_iter(200_000));
    let (x, last_step) = &r.param;
    assert_eq!(p.violations(x), 0);
    assert!(*last_step < 1e-9, "final pursuit step {last_step}");
}

#[test]
fn apex_reduce_codec_is_stable_across_jobs() {
    for (job, elem) in [
        (JOB_FEASIBILITY, ApexReduce::Corr(vec![0.25; 7])),
        (JOB_PURSUIT, ApexReduce::MinStep(1.5)),
        (JOB_VERIFY, ApexReduce::MaxViol(2.5)),
    ] {
        let bytes = (Some(elem.clone()), 3u64).to_bytes();
        let (decoded, counter) = <(Option<ApexReduce>, u64)>::from_bytes(&bytes);
        assert_eq!(decoded, Some(elem), "job {job}");
        assert_eq!(counter, 3);
    }
}

#[test]
fn apex_objective_monotone_improvement_over_start() {
    let p = ApexProblem::random(40, 6, 302);
    let start_obj = p.objective(&vec![0.0; 6]);
    let p = Arc::new(p);
    let r = run_threaded(Arc::clone(&p), &BsfConfig::with_workers(2).max_iter(200_000));
    assert!(p.objective(&r.param.0) > start_obj);
}
