//! Integration: workflow (multi-job) support through the Apex problem and
//! a purpose-built 2-job toy that checks job plumbing exactly.

use std::sync::Arc;

use bsf::problems::apex::{ApexProblem, ApexReduce, JOB_FEASIBILITY, JOB_PURSUIT, JOB_VERIFY};
use bsf::skeleton::problem::{BsfProblem, IterCtx, MapCtx};
use bsf::skeleton::{Bsf, StepDecision};
use bsf::util::codec::Codec;
use bsf::BsfError;

/// Toy 2-job workflow: job 0 sums elements, job 1 counts them; the
/// dispatcher alternates jobs and exits after 6 iterations. Verifies the
/// job number travels to workers and the per-job reduce dispatch works.
struct TwoJob {
    n: usize,
}

impl BsfProblem for TwoJob {
    type Param = Vec<f64>; // [iterations_done, sum_acc, count_acc]
    type MapElem = usize;
    type ReduceElem = (u64, f64);

    fn list_size(&self) -> usize {
        self.n
    }

    fn map_list_elem(&self, i: usize) -> usize {
        i
    }

    fn init_parameter(&self) -> Vec<f64> {
        vec![0.0, 0.0, 0.0]
    }

    fn job_count(&self) -> usize {
        2
    }

    fn map_f(&self, &i: &usize, _param: &Vec<f64>, ctx: &MapCtx) -> Option<(u64, f64)> {
        match ctx.job_case {
            0 => Some((0, i as f64)),  // sum job
            1 => Some((1, 1.0)),       // count job
            j => panic!("job {j}"),
        }
    }

    fn reduce_f(&self, x: &(u64, f64), y: &(u64, f64), job: usize) -> (u64, f64) {
        assert_eq!(x.0 as usize, job, "payload tagged with wrong job");
        assert_eq!(y.0 as usize, job);
        (x.0, x.1 + y.1)
    }

    fn process_results(
        &self,
        reduce_result: Option<&(u64, f64)>,
        reduce_counter: u64,
        param: &mut Vec<f64>,
        ctx: &IterCtx,
    ) -> StepDecision {
        let (tag, val) = reduce_result.copied().unwrap();
        assert_eq!(reduce_counter as usize, self.n);
        assert_eq!(tag as usize, ctx.job_case);
        param[0] += 1.0;
        if ctx.job_case == 0 {
            param[1] = val;
            StepDecision::goto(1)
        } else {
            param[2] = val;
            StepDecision::goto(0)
        }
    }

    fn job_dispatcher(
        &self,
        param: &mut Vec<f64>,
        decision: StepDecision,
        _ctx: &IterCtx,
    ) -> Option<StepDecision> {
        if param[0] >= 6.0 && !decision.exit {
            Some(StepDecision::exit())
        } else {
            None
        }
    }
}

#[test]
fn two_job_workflow_alternates_and_dispatcher_exits() {
    let n = 10;
    let r = Bsf::new(TwoJob { n }).workers(3).run().unwrap();
    assert_eq!(r.iterations, 6);
    assert_eq!(r.param[1], (0..n).sum::<usize>() as f64); // sum job result
    assert_eq!(r.param[2], n as f64); // count job result
}

#[test]
fn two_job_result_independent_of_workers() {
    let r1 = Bsf::new(TwoJob { n: 12 }).workers(1).run().unwrap();
    let r4 = Bsf::new(TwoJob { n: 12 }).workers(4).run().unwrap();
    assert_eq!(r1.param, r4.param);
    assert_eq!(r1.iterations, r4.iterations);
}

#[test]
fn apex_three_jobs_run_and_converge() {
    let p = ApexProblem::random(32, 5, 301);
    let p = Arc::new(p);
    let r = Bsf::from_arc(Arc::clone(&p))
        .workers(4)
        .max_iter(200_000)
        .run()
        .unwrap();
    let (x, last_step) = &r.param;
    assert_eq!(p.violations(x), 0);
    assert!(*last_step < 1e-9, "final pursuit step {last_step}");
}

#[test]
fn apex_reduce_codec_is_stable_across_jobs() {
    for (job, elem) in [
        (JOB_FEASIBILITY, ApexReduce::Corr(vec![0.25; 7])),
        (JOB_PURSUIT, ApexReduce::MinStep(1.5)),
        (JOB_VERIFY, ApexReduce::MaxViol(2.5)),
    ] {
        let bytes = (Some(elem.clone()), 3u64).to_bytes();
        let (decoded, counter) = <(Option<ApexReduce>, u64)>::from_bytes(&bytes);
        assert_eq!(decoded, Some(elem), "job {job}");
        assert_eq!(counter, 3);
    }
}

#[test]
fn apex_objective_monotone_improvement_over_start() {
    let p = ApexProblem::random(40, 6, 302);
    let start_obj = p.objective(&vec![0.0; 6]);
    let p = Arc::new(p);
    let r = Bsf::from_arc(Arc::clone(&p))
        .workers(2)
        .max_iter(200_000)
        .run()
        .unwrap();
    assert!(p.objective(&r.param.0) > start_obj);
}

/// A problem that reports an out-of-range job count: the session must
/// return a typed configuration error, not panic.
struct BadJobCount;

impl BsfProblem for BadJobCount {
    type Param = u64;
    type MapElem = usize;
    type ReduceElem = u64;

    fn list_size(&self) -> usize {
        4
    }
    fn map_list_elem(&self, i: usize) -> usize {
        i
    }
    fn init_parameter(&self) -> u64 {
        0
    }
    fn job_count(&self) -> usize {
        9 // > MAX_JOBS
    }
    fn map_f(&self, _: &usize, _: &u64, _: &MapCtx) -> Option<u64> {
        Some(1)
    }
    fn reduce_f(&self, x: &u64, y: &u64, _job: usize) -> u64 {
        x + y
    }
    fn process_results(
        &self,
        _r: Option<&u64>,
        _c: u64,
        _p: &mut u64,
        _ctx: &IterCtx,
    ) -> StepDecision {
        StepDecision::exit()
    }
}

#[test]
fn out_of_range_job_count_is_typed_error() {
    let err = Bsf::new(BadJobCount).workers(2).run().unwrap_err();
    assert!(matches!(err, BsfError::Config(_)), "{err}");
    assert!(err.to_string().contains("job_count"), "{err}");
}

/// A problem whose dispatcher jumps to a job that does not exist: the
/// master must broadcast exit (so workers terminate) and report a typed
/// error instead of asserting.
struct BadNextJob;

impl BsfProblem for BadNextJob {
    type Param = u64;
    type MapElem = usize;
    type ReduceElem = u64;

    fn list_size(&self) -> usize {
        4
    }
    fn map_list_elem(&self, i: usize) -> usize {
        i
    }
    fn init_parameter(&self) -> u64 {
        0
    }
    fn job_count(&self) -> usize {
        2
    }
    fn map_f(&self, _: &usize, _: &u64, _: &MapCtx) -> Option<u64> {
        Some(1)
    }
    fn reduce_f(&self, x: &u64, y: &u64, _job: usize) -> u64 {
        x + y
    }
    fn process_results(
        &self,
        _r: Option<&u64>,
        _c: u64,
        _p: &mut u64,
        _ctx: &IterCtx,
    ) -> StepDecision {
        StepDecision::goto(7) // out of range
    }
}

#[test]
fn out_of_range_next_job_is_typed_error_not_deadlock() {
    for k in [1usize, 3] {
        let err = Bsf::new(BadNextJob).workers(k).run().unwrap_err();
        assert!(matches!(err, BsfError::Config(_)), "K={k}: {err}");
        assert!(err.to_string().contains("next_job"), "K={k}: {err}");
    }
}
