//! Integration: the iteration-driver API — driver/one-shot equivalence
//! on every engine, declarative stop policies, cooperative cancellation
//! (threads and real OS processes), checkpoint/resume bit-identity, and
//! persistent clusters reusing worker processes across runs.

use std::time::Duration;

use bsf::costmodel::ClusterProfile;
use bsf::problems::jacobi::JacobiProblem;
use bsf::problems::montecarlo::MonteCarloProblem;
use bsf::skeleton::{Checkpoint, Cluster, StopPolicy, StopReason};
use bsf::util::codec::Codec;
use bsf::{
    Bsf, BsfError, CancelToken, ProcessEngine, SerialEngine, SimulatedEngine,
    ThreadedEngine,
};

const BSF_BIN: &str = env!("CARGO_BIN_EXE_bsf");

fn jacobi_worker_argv(n: usize) -> Vec<String> {
    [
        "worker", "--problem", "jacobi", "--n", &n.to_string(), "--seed", "7",
        "--eps", "1e-12",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect()
}

/// Step a fresh run to completion by hand and compare against the plain
/// one-shot `run()` of the same engine: bit-identical params, equal
/// iteration counts, one event per iteration.
fn assert_driver_matches_one_shot<E, F>(mk_engine: F, workers: usize, name: &str)
where
    E: bsf::Engine<JacobiProblem> + 'static,
    F: Fn() -> E,
{
    let (p1, _) = JacobiProblem::random(32, 1e-14, 7);
    let one_shot = Bsf::new(p1).workers(workers).engine(mk_engine()).run().unwrap();

    let (p2, _) = JacobiProblem::random(32, 1e-14, 7);
    let mut run = Bsf::new(p2).workers(workers).engine(mk_engine()).iterate().unwrap();
    let mut events = Vec::new();
    while !run.stopped() {
        events.push(run.step().unwrap());
    }
    let stepped = run.finish().unwrap();

    assert_eq!(stepped.iterations, one_shot.iterations, "{name}: iteration count");
    assert_eq!(stepped.param, one_shot.param, "{name}: bit-identical final param");
    assert_eq!(events.len(), one_shot.iterations, "{name}: one event per iteration");
    assert!(events.last().unwrap().stop.is_some(), "{name}: final event stops");
    assert_eq!(
        events.last().unwrap().param.as_ref(),
        Some(&one_shot.param),
        "{name}: stop event carries the final param"
    );
}

#[test]
fn driver_matches_one_shot_serial() {
    assert_driver_matches_one_shot(|| SerialEngine, 1, "serial");
}

#[test]
fn driver_matches_one_shot_threaded() {
    assert_driver_matches_one_shot(|| ThreadedEngine, 3, "threaded");
}

#[test]
fn driver_matches_one_shot_simulated() {
    assert_driver_matches_one_shot(
        || SimulatedEngine::new(ClusterProfile::infiniband()),
        3,
        "simulated",
    );
}

#[test]
fn driver_matches_one_shot_process() {
    let n = 32;
    let mk = || ProcessEngine::spawn_args(jacobi_worker_argv(n)).program(BSF_BIN);

    let (p1, _) = JacobiProblem::random(n, 1e-12, 7);
    let one_shot = Bsf::new(p1).workers(2).engine(mk()).run().unwrap();

    let (p2, _) = JacobiProblem::random(n, 1e-12, 7);
    let mut run = Bsf::new(p2).workers(2).engine(mk()).iterate().unwrap();
    assert_eq!(run.engine(), "process");
    let mut steps = 0usize;
    while !run.stopped() {
        run.step().unwrap();
        steps += 1;
    }
    let stepped = run.finish().unwrap();
    assert_eq!(stepped.iterations, one_shot.iterations);
    assert_eq!(steps, one_shot.iterations);
    assert_eq!(stepped.param, one_shot.param, "process: bit-identical");
    // The worker reports crossed the boundary with real child pids.
    assert_eq!(stepped.workers.len(), 2);
    assert!(stepped.workers.iter().all(|w| w.pid != 0 && w.pid != std::process::id()));
}

#[test]
fn events_expose_the_iteration_structure() {
    let (p, _) = JacobiProblem::random(24, 1e-14, 11);
    let run = Bsf::new(p).workers(1).iterate().unwrap();
    let events: Vec<_> = run.map(|e| e.unwrap()).collect();
    assert!(!events.is_empty());
    for (i, ev) in events.iter().enumerate() {
        assert_eq!(ev.iter, i + 1, "dense 1-based iteration counter");
        assert_eq!(ev.job_case, 0, "jacobi has a single job");
        assert!(ev.reduce_counter > 0, "every element participates");
    }
    for pair in events.windows(2) {
        assert!(pair[1].elapsed >= pair[0].elapsed, "elapsed is monotone");
    }
    assert_eq!(events.last().unwrap().stop, Some(StopReason::Converged));
}

#[test]
fn stop_policy_max_iter_deadline_and_predicate() {
    // Unreachable eps: only the policy can stop these runs.
    let mk = || JacobiProblem::random(16, 1e-300, 5).0;

    let r = Bsf::new(mk())
        .workers(1)
        .stop(StopPolicy::new().max_iter(5))
        .iterate()
        .unwrap();
    let events: Vec<_> = r.map(|e| e.unwrap()).collect();
    assert_eq!(events.len(), 5);
    assert_eq!(events.last().unwrap().stop, Some(StopReason::MaxIter));

    // A zero deadline stops after the first iteration (checked at the
    // decision step — the running iteration completes).
    let r = Bsf::new(mk())
        .workers(2)
        .engine(ThreadedEngine)
        .deadline(Duration::ZERO)
        .iterate()
        .unwrap();
    let events: Vec<_> = r.map(|e| e.unwrap()).collect();
    assert_eq!(events.len(), 1);
    assert_eq!(events.last().unwrap().stop, Some(StopReason::Deadline));

    let r = Bsf::new(mk())
        .workers(1)
        .stop(StopPolicy::new().until(|ctx| ctx.iter_counter >= 3))
        .run()
        .unwrap();
    assert_eq!(r.iterations, 3);

    // The policy rides the config into the simulator too (virtual clock).
    let r = Bsf::new(mk())
        .workers(2)
        .engine(SimulatedEngine::new(ClusterProfile::ideal()))
        .stop(StopPolicy::new().max_iter(4))
        .run()
        .unwrap();
    assert_eq!(r.iterations, 4);
}

#[test]
fn stop_policy_caps_compose_with_max_iter() {
    let (p, _) = JacobiProblem::random(16, 1e-300, 5);
    // The lower of the two caps wins.
    let r = Bsf::new(p)
        .workers(1)
        .max_iter(3)
        .stop(StopPolicy::new().max_iter(50))
        .run()
        .unwrap();
    assert_eq!(r.iterations, 3);
}

#[test]
fn cancel_aborts_threaded_run_between_iterations() {
    let (p, _) = JacobiProblem::random(32, 1e-300, 6);
    let token = CancelToken::new();
    let mut run = Bsf::new(p)
        .workers(3)
        .engine(ThreadedEngine)
        .cancel_token(token.clone())
        .iterate()
        .unwrap();
    // A couple of normal iterations, then cancel.
    run.step().unwrap();
    run.step().unwrap();
    token.cancel();
    let err = run.step().unwrap_err();
    assert!(matches!(err, BsfError::Cancelled), "{err}");
    // Dropping the run joins the (released) worker threads — if the
    // release had not happened this test would hang, not pass.
    drop(run);
}

#[test]
fn cancel_aborts_one_shot_run_from_another_thread() {
    let (p, _) = JacobiProblem::random(700, 1e-300, 6);
    let token = CancelToken::new();
    let cancel_from_outside = {
        let token = token.clone();
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            token.cancel();
        })
    };
    let err = Bsf::new(p)
        .workers(2)
        .engine(ThreadedEngine)
        .max_iter(50_000_000)
        .cancel_token(token)
        .run()
        .unwrap_err();
    assert!(matches!(err, BsfError::Cancelled), "{err}");
    cancel_from_outside.join().unwrap();
}

#[test]
fn cancel_aborts_process_run_and_reaps_workers() {
    let n = 32;
    let (p, _) = JacobiProblem::random(n, 1e-300, 7);
    let token = CancelToken::new();
    let engine = ProcessEngine::spawn_args(jacobi_worker_argv(n)).program(BSF_BIN);
    let mut run = Bsf::new(p)
        .workers(2)
        .engine(engine)
        .max_iter(50_000_000)
        .cancel_token(token.clone())
        .iterate()
        .unwrap();
    run.step().unwrap();
    token.cancel();
    let err = run.step().unwrap_err();
    assert!(matches!(err, BsfError::Cancelled), "{err}");
    // Dropping the run kills + reaps the released child processes; the
    // typed error above plus a clean return here is the no-hang proof.
    drop(run);
}

#[test]
fn checkpoint_resume_is_bit_identical_threaded() {
    let k = 2;
    let (p, _) = JacobiProblem::random(48, 1e-16, 9);
    let full = Bsf::new(p).workers(k).engine(ThreadedEngine).run().unwrap();
    assert!(full.iterations >= 4, "need a mid-run point to checkpoint at");

    // Step a fresh run halfway, checkpoint, abandon it.
    let mid = full.iterations / 2;
    let (p2, _) = JacobiProblem::random(48, 1e-16, 9);
    let mut run = Bsf::new(p2).workers(k).engine(ThreadedEngine).iterate().unwrap();
    for _ in 0..mid {
        run.step().unwrap();
    }
    let ck = run.checkpoint();
    assert_eq!(ck.iter, mid);
    let partial = run.finish().unwrap(); // early finish releases workers
    assert_eq!(partial.iterations, mid);

    // The checkpoint survives the wire (Codec round-trip)...
    let restored = Checkpoint::<Vec<f64>>::try_from_bytes(&ck.to_bytes()).unwrap();
    assert_eq!(restored, ck);

    // ...and the resumed run finishes bit-identically to the
    // uninterrupted one, iteration count included.
    let (p3, _) = JacobiProblem::random(48, 1e-16, 9);
    let resumed = Bsf::new(p3)
        .workers(k)
        .engine(ThreadedEngine)
        .resume(restored)
        .run()
        .unwrap();
    assert_eq!(resumed.iterations, full.iterations);
    assert_eq!(resumed.param, full.param, "resume must be bit-identical");
}

#[test]
fn checkpoint_resume_is_bit_identical_serial_and_simulated() {
    let (p, _) = JacobiProblem::random(32, 1e-14, 10);
    let full = Bsf::new(p).workers(1).run().unwrap();
    let mid = full.iterations / 2;
    assert!(mid >= 1);

    let (p2, _) = JacobiProblem::random(32, 1e-14, 10);
    let mut run = Bsf::new(p2).workers(1).iterate().unwrap();
    for _ in 0..mid {
        run.step().unwrap();
    }
    let ck = run.checkpoint();
    drop(run); // abandoning a serial driver needs no cleanup

    let (p3, _) = JacobiProblem::random(32, 1e-14, 10);
    let resumed = Bsf::new(p3).workers(1).resume(ck.clone()).run().unwrap();
    assert_eq!(resumed.iterations, full.iterations);
    assert_eq!(resumed.param, full.param);

    // The same checkpoint resumes on the simulator (same math, same K):
    // identical numerics on the virtual cluster.
    let (p4, _) = JacobiProblem::random(32, 1e-14, 10);
    let sim = Bsf::new(p4)
        .workers(1)
        .engine(SimulatedEngine::new(ClusterProfile::gigabit()))
        .resume(ck)
        .run()
        .unwrap();
    assert_eq!(sim.iterations, full.iterations);
    assert_eq!(sim.param, full.param);
}

#[test]
fn checkpoint_resume_bit_identical_for_iteration_dependent_maps() {
    // Montecarlo's map seeds its per-element RNG with the iteration
    // counter, so resume is bit-identical only because the order message
    // ships the master's counter to the workers — a worker whose counter
    // rebased to 0 after resume would sample a different stream.
    let mk = || {
        let mut p = MonteCarloProblem::new(12, 300, 1e-12);
        p.max_rounds = 6;
        p
    };
    let full = Bsf::new(mk()).workers(2).engine(ThreadedEngine).run().unwrap();
    let mid = full.iterations / 2;
    assert!(mid >= 1, "need a mid-run checkpoint point");

    let mut run = Bsf::new(mk()).workers(2).engine(ThreadedEngine).iterate().unwrap();
    for _ in 0..mid {
        run.step().unwrap();
    }
    let ck = run.checkpoint();
    run.finish().unwrap();

    let resumed = Bsf::new(mk())
        .workers(2)
        .engine(ThreadedEngine)
        .resume(ck)
        .run()
        .unwrap();
    assert_eq!(resumed.iterations, full.iterations);
    assert_eq!(
        resumed.param, full.param,
        "iteration-counter-dependent map must resume bit-identically"
    );
}

#[test]
fn checkpoint_with_bad_job_is_rejected_at_launch() {
    let (p, _) = JacobiProblem::random(16, 1e-12, 11);
    let err = Bsf::new(p)
        .workers(1)
        .resume(Checkpoint { param: vec![0.0; 16], iter: 3, job: 7 })
        .run()
        .unwrap_err();
    assert!(matches!(err, BsfError::Config(_)), "{err}");
    assert!(err.to_string().contains("job"), "{err}");
}

#[test]
fn early_finish_reports_the_partial_run() {
    let (p, _) = JacobiProblem::random(32, 1e-300, 12);
    let mut run = Bsf::new(p).workers(2).engine(ThreadedEngine).iterate().unwrap();
    for _ in 0..3 {
        run.step().unwrap();
    }
    let report = run.finish().unwrap();
    assert_eq!(report.iterations, 3);
    assert_eq!(report.workers.len(), 2, "workers joined cleanly");
    assert!(report.workers.iter().all(|w| w.iterations == 3));
}

#[test]
fn cluster_reuses_worker_processes_across_runs() {
    let n = 32;
    let (p, _) = JacobiProblem::random(n, 1e-12, 7);
    let cluster = Cluster::spawn(2, jacobi_worker_argv(n))
        .program(BSF_BIN)
        .start(&p)
        .unwrap();
    assert_eq!(cluster.workers(), 2);

    // Reference numerics: the threaded engine is bit-identical to the
    // process protocol at the same K (rank-ordered fold, lossless codec).
    let (pt, _) = JacobiProblem::random(n, 1e-12, 7);
    let fresh = Bsf::new(pt).workers(2).engine(ThreadedEngine).run().unwrap();

    let run_on_cluster = || {
        let (pc, _) = JacobiProblem::random(n, 1e-12, 7);
        Bsf::new(pc).workers(2).engine(cluster.engine()).run().unwrap()
    };
    let r1 = run_on_cluster();
    let r2 = run_on_cluster();

    for r in [&r1, &r2] {
        assert_eq!(r.engine, "cluster");
        assert_eq!(r.iterations, fresh.iterations);
        assert_eq!(r.param, fresh.param, "cluster runs match fresh-spawn numerics");
        assert_eq!(r.workers.len(), 2);
    }
    // Per-run traffic accounting (not cluster-lifetime cumulative):
    // K orders + K folds + K exit flags per iteration, plus K NEWRUNs
    // and K end-of-run reports on the user tag.
    let iters = r1.iterations as u64;
    for r in [&r1, &r2] {
        assert_eq!(r.volume.order.messages, 2 * iters);
        assert_eq!(r.volume.fold.messages, 2 * iters);
        assert_eq!(r.volume.exit.messages, 2 * iters);
        assert_eq!(r.volume.user.messages, 4, "2 NEWRUN + 2 worker reports");
        assert_eq!(r.messages, r.volume.total_messages());
    }

    // THE amortization witness: both runs were served by the same
    // worker OS processes.
    for w in 0..2 {
        assert_eq!(r1.workers[w].rank, w);
        assert_ne!(r1.workers[w].pid, 0);
        assert_ne!(r1.workers[w].pid, std::process::id());
        assert_eq!(
            r1.workers[w].pid, r2.workers[w].pid,
            "run 2 must reuse run 1's worker process"
        );
    }

    // The Iterator pattern consumes the BsfRun without finish(); a
    // cleanly stopped (or merely abandoned-between-iterations) run must
    // park the pool back, not kill it.
    let (pi, _) = JacobiProblem::random(n, 1e-12, 7);
    let run = Bsf::new(pi).workers(2).engine(cluster.engine()).iterate().unwrap();
    for event in run {
        event.unwrap();
    } // dropped here without finish()
    let r3 = run_on_cluster();
    assert_eq!(r3.param, fresh.param);
    assert_eq!(
        r3.workers[0].pid, r1.workers[0].pid,
        "drop-without-finish must hand the workers back"
    );

    cluster.shutdown().unwrap();
}

#[test]
fn cluster_is_busy_while_a_run_is_active_and_shuts_down_cleanly() {
    let n = 24;
    let (p, _) = JacobiProblem::random(n, 1e-12, 8);
    let cluster = Cluster::spawn(1, jacobi_worker_argv(n))
        .program(BSF_BIN)
        .start(&p)
        .unwrap();

    let (p1, _) = JacobiProblem::random(n, 1e-12, 8);
    let mut active = Bsf::new(p1).workers(1).engine(cluster.engine()).iterate().unwrap();
    active.step().unwrap();

    // One run at a time: a second launch is the typed busy error,
    // carrying how many jobs hold the fleet and pointing at `bsf serve`
    // + `bsf submit` as the non-racing alternative.
    let (p2, _) = JacobiProblem::random(n, 1e-12, 8);
    let err = Bsf::new(p2).workers(1).engine(cluster.engine()).run().unwrap_err();
    assert!(matches!(err, BsfError::ClusterBusy { active_jobs: 1 }), "{err}");
    assert!(err.to_string().contains("bsf serve"), "{err}");

    // Finishing the active run frees the pool for the next one.
    let r1 = active.run_to_end().unwrap();
    let (p3, _) = JacobiProblem::random(n, 1e-12, 8);
    let r2 = Bsf::new(p3).workers(1).engine(cluster.engine()).run().unwrap();
    assert_eq!(r1.param, r2.param);
    assert_eq!(r1.workers[0].pid, r2.workers[0].pid);

    // The worker-count contract is checked, not assumed.
    let (p4, _) = JacobiProblem::random(n, 1e-12, 8);
    let err = Bsf::new(p4).workers(3).engine(cluster.engine()).run().unwrap_err();
    assert!(matches!(err, BsfError::Config(_)), "{err}");

    // ...and so is the problem signature: a different problem instance
    // is a typed config error (the process engine's handshake guard,
    // per run), and the rejected launch must not consume the pool.
    let (pw, _) = JacobiProblem::random(2 * n, 1e-12, 8);
    let err = Bsf::new(pw).workers(1).engine(cluster.engine()).run().unwrap_err();
    assert!(matches!(err, BsfError::Config(_)), "{err}");
    assert!(err.to_string().contains("list_size"), "{err}");

    cluster.shutdown().unwrap();
}

#[test]
fn cancelled_cluster_run_leaves_the_cluster_reusable() {
    let n = 24;
    let (p, _) = JacobiProblem::random(n, 1e-300, 9);
    let cluster = Cluster::spawn(1, jacobi_worker_argv(n))
        .program(BSF_BIN)
        .start(&p)
        .unwrap();

    let token = CancelToken::new();
    let (p1, _) = JacobiProblem::random(n, 1e-300, 9);
    let mut run = Bsf::new(p1)
        .workers(1)
        .engine(cluster.engine())
        .max_iter(50_000_000)
        .cancel_token(token.clone())
        .iterate()
        .unwrap();
    run.step().unwrap();
    token.cancel();
    let err = run.step().unwrap_err();
    assert!(matches!(err, BsfError::Cancelled), "{err}");
    // Like every other engine, finish() after a cancel still reports
    // the partial run — even though the pool was already handed back.
    let partial = run.finish().unwrap();
    assert_eq!(partial.engine, "cluster");
    assert_eq!(partial.iterations, 1);
    assert_eq!(partial.workers.len(), 1);

    // Cancellation released the worker back to its idle loop; the
    // cluster still serves runs with the same process.
    let (p2, _) = JacobiProblem::random(n, 1e-300, 9);
    let r = Bsf::new(p2)
        .workers(1)
        .engine(cluster.engine())
        .max_iter(5)
        .run()
        .unwrap();
    assert_eq!(r.iterations, 5);
    cluster.shutdown().unwrap();
}
