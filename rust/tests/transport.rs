//! Transport-layer integration tests: MPI-style selective receive with
//! out-of-order buffering, completion-order gathering with K ≥ 4
//! workers, and byte/message accounting in `TransportStats`.

use std::sync::mpsc::channel;
use std::thread;

use bsf::transport::{build_thread_transport, Communicator, Tag, ThreadEndpoint};

fn split_master(k: usize) -> (ThreadEndpoint, Vec<ThreadEndpoint>) {
    let mut eps = build_thread_transport(k);
    let master = eps.pop().unwrap();
    (master, eps)
}

#[test]
fn recv_buffers_out_of_order_arrivals_across_peers_and_tags() {
    let (master, workers) = split_master(3);
    // Workers send in a deliberately scrambled order: rank r sends its
    // Fold first, then an Exit, then a User message.
    let handles: Vec<_> = workers
        .into_iter()
        .map(|w| {
            thread::spawn(move || {
                let r = w.rank() as u8;
                w.send(3, Tag::Fold, vec![r, 0]).unwrap();
                w.send(3, Tag::Exit, vec![r, 1]).unwrap();
                w.send(3, Tag::User(9), vec![r, 2]).unwrap();
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    // Ask in the *reverse* tag order and in reverse rank order: every
    // message must still be delivered, none lost, none crossed.
    for r in (0..3usize).rev() {
        let m = master.recv(r, Tag::User(9)).unwrap();
        assert_eq!(m.payload, vec![r as u8, 2]);
    }
    for r in 0..3usize {
        let m = master.recv(r, Tag::Exit).unwrap();
        assert_eq!(m.payload, vec![r as u8, 1]);
        let m = master.recv(r, Tag::Fold).unwrap();
        assert_eq!(m.payload, vec![r as u8, 0]);
    }
}

#[test]
fn recv_from_specific_peer_skips_other_peers() {
    let (master, mut workers) = split_master(2);
    let w1 = workers.pop().unwrap();
    let w0 = workers.pop().unwrap();
    w1.send(2, Tag::Fold, vec![11]).unwrap();
    w0.send(2, Tag::Fold, vec![10]).unwrap();
    // Selective receive from rank 1 must not consume rank 0's message.
    assert_eq!(master.recv(1, Tag::Fold).unwrap().payload, vec![11]);
    assert_eq!(master.recv(0, Tag::Fold).unwrap().payload, vec![10]);
}

#[test]
fn recv_any_gathers_in_completion_order_k5() {
    // K = 5 workers complete in a *controlled* order (each waits for a
    // go-token released only after the previous worker's fold has been
    // received); recv_any must yield messages in completion order
    // (MPI_Waitany semantics), which the master relies on to overlap
    // gathering with stragglers. The token chain makes the expected
    // order deterministic — no sleeps, no scheduler dependence.
    let k = 5;
    let (master, workers) = split_master(k);
    let mut go_tx = Vec::with_capacity(k);
    let mut go_rx = Vec::with_capacity(k);
    for _ in 0..k {
        let (tx, rx) = channel::<()>();
        go_tx.push(tx);
        go_rx.push(Some(rx));
    }
    let handles: Vec<_> = workers
        .into_iter()
        .map(|w| {
            let rx = go_rx[w.rank()].take().expect("one receiver per rank");
            thread::spawn(move || {
                rx.recv().unwrap(); // wait until it is this rank's turn
                w.send(w.master_rank(), Tag::Fold, vec![w.rank() as u8]).unwrap();
            })
        })
        .collect();
    // Completion order is the *reverse* of rank order by construction.
    for expect in (0..k).rev() {
        go_tx[expect].send(()).unwrap();
        let m = master.recv_any(Tag::Fold).unwrap();
        assert_eq!(m.payload, vec![expect as u8], "completion order violated");
        assert_eq!(m.from, expect);
    }
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn stats_account_bytes_and_messages_exactly() {
    let (master, workers) = split_master(4);
    let stats = master.stats();
    assert_eq!(stats.message_count(), 0);
    assert_eq!(stats.byte_count(), 0);

    // Master broadcasts 3 orders of 10 bytes to the first 3 workers...
    for w in 0..3 {
        master.send(w, Tag::Order, vec![0; 10]).unwrap();
    }
    // ...and every worker sends a fold of (rank+1) bytes back.
    let handles: Vec<_> = workers
        .into_iter()
        .map(|w| {
            thread::spawn(move || {
                let rank = w.rank();
                w.send(4, Tag::Fold, vec![0; rank + 1]).unwrap();
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    for _ in 0..4 {
        master.recv_any(Tag::Fold).unwrap();
    }
    // Totals are shared across all endpoints of the run:
    // 3*10 order bytes + (1+2+3+4) fold bytes; 3 + 4 messages.
    assert_eq!(stats.message_count(), 7);
    assert_eq!(stats.byte_count(), 30 + 10);
    // Receiving does not change the counters.
    assert_eq!(master.stats().byte_count(), 40);
}

#[test]
fn recv_tags_matches_first_of_either_tag_in_arrival_order() {
    let (master, mut workers) = split_master(1);
    let w = workers.pop().unwrap();
    w.send(1, Tag::Order, vec![1]).unwrap();
    w.send(1, Tag::Abort, vec![2]).unwrap();
    w.send(1, Tag::Order, vec![3]).unwrap();
    // Multi-tag receive drains in arrival order across both tags...
    let m = master.recv_tags(Some(0), &[Tag::Order, Tag::Abort]).unwrap();
    assert_eq!(m.tag, Tag::Order);
    assert_eq!(m.payload, vec![1]);
    let m = master.recv_tags(Some(0), &[Tag::Order, Tag::Abort]).unwrap();
    assert_eq!(m.tag, Tag::Abort);
    assert_eq!(m.payload, vec![2]);
    // ...while a single-tag receive still skips and buffers nothing else.
    let m = master.recv(0, Tag::Order).unwrap();
    assert_eq!(m.payload, vec![3]);
}

#[test]
fn zero_length_payloads_count_as_messages_not_bytes() {
    let (master, mut workers) = split_master(1);
    let w = workers.pop().unwrap();
    w.send(1, Tag::Fold, vec![]).unwrap();
    assert_eq!(master.recv(0, Tag::Fold).unwrap().payload.len(), 0);
    assert_eq!(master.stats().message_count(), 1);
    assert_eq!(master.stats().byte_count(), 0);
}

#[test]
fn heavy_interleaving_preserves_per_peer_fifo() {
    // Two workers each send 100 numbered Fold messages while the master
    // interleaves selective receives; per-peer FIFO must hold (MPI's
    // non-overtaking guarantee).
    let (master, workers) = split_master(2);
    let handles: Vec<_> = workers
        .into_iter()
        .map(|w| {
            thread::spawn(move || {
                for i in 0..100u8 {
                    w.send(2, Tag::Fold, vec![w.rank() as u8, i]).unwrap();
                }
            })
        })
        .collect();
    let mut next = [0u8; 2];
    for _ in 0..200 {
        let m = master.recv_any(Tag::Fold).unwrap();
        assert_eq!(m.payload.len(), 2);
        let (rank, seq) = (m.payload[0], m.payload[1]);
        assert_eq!(seq, next[rank as usize], "peer {rank} overtook itself");
        next[rank as usize] += 1;
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(next, [100, 100]);
}
