//! Fault-tolerance chaos matrix: kill a worker at iteration {first,
//! mid, last} × engine {threaded, simulated, process, cluster} × policy
//! {Abort, Redistribute, RestartFromCheckpoint}.
//!
//! The load-bearing assertion, per the fault-layer contract: with
//! `FaultPolicy::Redistribute`, a run that loses a worker completes with
//! results **bit-identical to a fresh (K−1)-worker run** — the master
//! re-splits the list over the survivors with the canonical block split
//! and merges partial folds in logical-rank order, so the recovered
//! run's fold tree *is* the fresh run's fold tree.
//!
//! Problem choice: montecarlo's map streams are keyed by (block,
//! iteration) and its reduce is an exact integer sum, so its trajectory
//! is identical for every worker count — which makes mid-run kills
//! comparable against a fresh (K−1) run. Jacobi (dense float sums,
//! K-sensitive association) covers the kill-before-first-merge case,
//! where bit-identity must hold for *every* problem.
//!
//! Threaded-engine kills are injected with `util::faultsim`'s
//! deterministic partition script (real worker threads, wrapped master
//! endpoint); process/cluster kills are real child-process deaths via
//! the `--kill-rank R --kill-after-folds N` worker flags.

use bsf::costmodel::ClusterProfile;
use bsf::problems::jacobi::JacobiProblem;
use bsf::problems::montecarlo::MonteCarloProblem;
use bsf::simcluster::{FaultPlan, SimConfig};
use bsf::skeleton::FaultPolicy;
use bsf::util::faultsim::{FaultScript, FlakyThreadedEngine};
use bsf::{
    Bsf, BsfError, Cluster, ProcessEngine, RunReport, SimulatedEngine, ThreadedEngine,
};

const BSF_BIN: &str = env!("CARGO_BIN_EXE_bsf");

/// Process/cluster-tier montecarlo shape; tolerance matches the CLI's
/// fixed 1e-3 so master and spawned workers build identical instances.
const MC_BLOCKS: usize = 4;
const MC_SAMPLES: usize = 50_000;

fn mc_process() -> MonteCarloProblem {
    MonteCarloProblem::new(MC_BLOCKS, MC_SAMPLES, 1e-3)
}

fn mc_worker_argv(kill: Option<(usize, usize)>) -> Vec<String> {
    let mut argv: Vec<String> = vec![
        "worker".into(),
        "--problem".into(),
        "montecarlo".into(),
        "--n".into(),
        MC_BLOCKS.to_string(),
        "--samples".into(),
        MC_SAMPLES.to_string(),
    ];
    if let Some((rank, folds)) = kill {
        argv.extend([
            "--kill-rank".into(),
            rank.to_string(),
            "--kill-after-folds".into(),
            folds.to_string(),
        ]);
    }
    argv
}

/// In-process (threaded/sim) montecarlo shape: quicker, any tolerance.
fn mc_threaded() -> MonteCarloProblem {
    MonteCarloProblem::new(6, 2_000, 5e-3)
}

/// Reference run: fresh threaded execution at `k` workers (the process
/// and cluster protocols are bit-identical to threaded at equal K).
fn fresh_threaded(p: MonteCarloProblem, k: usize) -> RunReport<(u64, u64)> {
    Bsf::new(p).workers(k).engine(ThreadedEngine).run().unwrap()
}

// ---------------------------------------------------------------------
// Threaded engine × injected partitions
// ---------------------------------------------------------------------

#[test]
fn threaded_redistribute_matches_fresh_k_minus_1_at_first_mid_and_last_iteration() {
    let baseline = fresh_threaded(mc_threaded(), 3);
    let n_iters = baseline.iterations;
    assert!(n_iters >= 3, "need a multi-iteration run, got {n_iters}");
    let fresh2 = fresh_threaded(mc_threaded(), 2);
    assert_eq!(
        fresh2.param, baseline.param,
        "montecarlo must be K-invariant for this matrix to be meaningful"
    );

    for kill_round in [0, n_iters / 2, n_iters - 1] {
        let script = FaultScript::new().kill(1, kill_round);
        let cfg = bsf::BsfConfig::with_workers(3).redistribute_on_loss(1);
        let report = Bsf::new(mc_threaded())
            .config(cfg)
            .engine(FlakyThreadedEngine::new(script))
            .run()
            .unwrap_or_else(|e| {
                panic!("redistribute run (kill@{kill_round}) failed: {e}")
            });
        assert_eq!(
            report.param, fresh2.param,
            "kill@{kill_round}: redistributed result must be bit-identical \
             to a fresh 2-worker run"
        );
        assert_eq!(report.iterations, fresh2.iterations, "kill@{kill_round}");
        assert_eq!(report.losses, vec![1], "kill@{kill_round}: loss recorded");
        // All three real worker threads joined cleanly (the partitioned
        // one was parked and released at teardown).
        assert_eq!(report.workers.len(), 3, "kill@{kill_round}");
        let survivor = report.workers.iter().find(|w| w.rank == 2).unwrap();
        assert!(
            survivor.reassignments >= 1,
            "kill@{kill_round}: survivor adopted the re-split"
        );
    }
}

#[test]
fn threaded_abort_policy_surfaces_the_typed_loss() {
    let script = FaultScript::new().kill(1, 1);
    let err = Bsf::new(mc_threaded())
        .workers(3)
        .engine(FlakyThreadedEngine::new(script))
        .run()
        .unwrap_err();
    assert!(matches!(err, BsfError::WorkerLost { rank: 1, .. }), "{err}");
}

#[test]
fn threaded_restart_from_checkpoint_matches_the_uninterrupted_run() {
    let baseline = fresh_threaded(mc_threaded(), 3);
    let mid = baseline.iterations / 2;
    let script = FaultScript::new().kill(1, mid);
    let cfg = bsf::BsfConfig::with_workers(3).fault(FaultPolicy::RestartFromCheckpoint);
    let report = Bsf::new(mc_threaded())
        .config(cfg)
        .engine(FlakyThreadedEngine::new(script))
        .run()
        .unwrap();
    // The relaunch resumed at full K from the master's checkpoint; the
    // order envelope carries the true iteration counter, so the
    // counter-seeded montecarlo streams continue bit-identically.
    assert_eq!(report.param, baseline.param);
    assert_eq!(report.iterations, baseline.iterations);
    assert_eq!(report.losses, vec![1], "restart recorded the triggering loss");
}

#[test]
fn threaded_rejoin_readmits_a_healed_worker_at_an_iteration_boundary() {
    let baseline = fresh_threaded(mc_threaded(), 3);
    assert!(baseline.iterations >= 4, "need room for kill+heal");
    // Partition rank 1 away at round 1, heal it one round later: the
    // master re-admits it via REJOIN and re-splits back to 3 workers.
    let script = FaultScript::new().kill(1, 1).heal(1, 2);
    let cfg = bsf::BsfConfig::with_workers(3).redistribute_on_loss(1);
    let report = Bsf::new(mc_threaded())
        .config(cfg)
        .engine(FlakyThreadedEngine::new(script))
        .run()
        .unwrap();
    // Montecarlo is K-invariant, so the shrink-then-regrow trajectory
    // still matches the uninterrupted run.
    assert_eq!(report.param, baseline.param);
    assert_eq!(report.iterations, baseline.iterations);
    assert_eq!(report.losses, vec![1], "the loss event stays on record");
    assert_eq!(report.rejoined, vec![1], "the re-admission is on record too");
    assert_eq!(report.workers.len(), 3);
    let rejoiner = report.workers.iter().find(|w| w.rank == 1).unwrap();
    assert!(rejoiner.reassignments >= 1, "rejoiner re-admitted with a new split");
    assert!(
        rejoiner.iterations < baseline.iterations,
        "rejoiner sat out at least one iteration"
    );
}

#[test]
fn jacobi_kill_before_first_merge_is_bit_identical_for_any_problem() {
    // Before the first merge no K-dependent association has happened,
    // so even a float-sum problem must match the fresh (K-1) run bit
    // for bit when the loss lands at round 0.
    let (fresh, _) = JacobiProblem::random(40, 1e-12, 11);
    let fresh2 = Bsf::new(fresh).workers(2).engine(ThreadedEngine).run().unwrap();

    let (p, _) = JacobiProblem::random(40, 1e-12, 11);
    let script = FaultScript::new().kill(0, 0);
    let cfg = bsf::BsfConfig::with_workers(3).redistribute_on_loss(1);
    let report = Bsf::new(p)
        .config(cfg)
        .engine(FlakyThreadedEngine::new(script))
        .run()
        .unwrap();
    assert_eq!(report.param, fresh2.param);
    assert_eq!(report.iterations, fresh2.iterations);
    assert_eq!(report.losses, vec![0]);
}

#[test]
fn threaded_redistribute_budget_exhaustion_aborts_typed() {
    // Two kills, budget one: the second loss must abort the run.
    let script = FaultScript::new().kill(0, 1).kill(2, 2);
    let cfg = bsf::BsfConfig::with_workers(3).redistribute_on_loss(1);
    let err = Bsf::new(mc_threaded())
        .config(cfg)
        .engine(FlakyThreadedEngine::new(script))
        .run()
        .unwrap_err();
    assert!(matches!(err, BsfError::WorkerLost { .. }), "{err}");
}

// ---------------------------------------------------------------------
// Simulated engine × FaultPlan
// ---------------------------------------------------------------------

#[test]
fn sim_fault_plan_redistribute_matches_fresh_k_minus_1() {
    let sim = || SimulatedEngine::with_config(SimConfig::new(ClusterProfile::ideal()));
    let fresh2 = Bsf::new(mc_threaded()).workers(2).engine(sim()).run().unwrap();
    let n_iters = fresh2.iterations;
    assert!(n_iters >= 3);

    for kill_iter in [0, n_iters / 2, n_iters - 1] {
        let plan = FaultPlan::new().kill(1, kill_iter);
        let faulted = SimulatedEngine::with_config(
            SimConfig::new(ClusterProfile::ideal()).fault(plan),
        );
        let cfg = bsf::BsfConfig::with_workers(3).redistribute_on_loss(1);
        let report =
            Bsf::new(mc_threaded()).config(cfg).engine(faulted).run().unwrap();
        assert_eq!(report.param, fresh2.param, "kill@{kill_iter}");
        assert_eq!(report.iterations, fresh2.iterations, "kill@{kill_iter}");
        assert_eq!(report.losses, vec![1], "kill@{kill_iter}");
        // The recovery bill was charged: the wasted round + the replan
        // control messages make the faulted run strictly longer in
        // virtual time than an unfaulted 3-worker run.
        assert!(report.elapsed > 0.0);
    }
}

#[test]
fn sim_fault_plan_abort_and_restart_policies() {
    let baseline = {
        let sim = SimulatedEngine::with_config(SimConfig::new(ClusterProfile::ideal()));
        Bsf::new(mc_threaded()).workers(3).engine(sim).run().unwrap()
    };
    let mid = baseline.iterations / 2;

    // Abort: the kill surfaces typed.
    let aborted = SimulatedEngine::with_config(
        SimConfig::new(ClusterProfile::ideal()).fault(FaultPlan::new().kill(2, mid)),
    );
    let err = Bsf::new(mc_threaded()).workers(3).engine(aborted).run().unwrap_err();
    assert!(matches!(err, BsfError::WorkerLost { rank: 2, .. }), "{err}");

    // RestartFromCheckpoint: the run relaunches at full K from the
    // master's checkpoint and finishes bit-identically to the
    // uninterrupted run — the workers' `SkelVars::iter_counter` resumed
    // at the true count (montecarlo's counter-seeded streams would
    // diverge otherwise). The FaultPlan's fired set is shared across
    // relaunch clones, so the kill does not re-fire.
    let restarted = SimulatedEngine::with_config(
        SimConfig::new(ClusterProfile::ideal()).fault(FaultPlan::new().kill(2, mid)),
    );
    let cfg = bsf::BsfConfig::with_workers(3).fault(FaultPolicy::RestartFromCheckpoint);
    let report = Bsf::new(mc_threaded()).config(cfg).engine(restarted).run().unwrap();
    assert_eq!(report.param, baseline.param);
    assert_eq!(report.iterations, baseline.iterations);
    assert_eq!(report.losses, vec![2]);
}

// ---------------------------------------------------------------------
// Process engine × real child-process deaths
// ---------------------------------------------------------------------

fn process_engine(kill: Option<(usize, usize)>) -> ProcessEngine {
    ProcessEngine::spawn_args(mc_worker_argv(kill)).program(BSF_BIN)
}

#[test]
fn process_redistribute_survives_a_real_worker_death_mid_run() {
    let fresh2 = fresh_threaded(mc_process(), 2);
    let baseline3 = fresh_threaded(mc_process(), 3);
    let mid = baseline3.iterations / 2;
    assert!(mid >= 1, "need a mid-run kill point");

    let cfg = bsf::BsfConfig::with_workers(3).redistribute_on_loss(1);
    let report = Bsf::new(mc_process())
        .config(cfg)
        .engine(process_engine(Some((1, mid))))
        .run()
        .unwrap();
    assert_eq!(report.engine, "process");
    assert_eq!(
        report.param, fresh2.param,
        "redistributed process run must be bit-identical to a fresh \
         2-worker run"
    );
    assert_eq!(report.iterations, fresh2.iterations);
    assert_eq!(report.losses, vec![1], "the loss is on record");
    // Only the survivors ship end-of-run reports.
    assert_eq!(report.workers.len(), 2);
    assert!(report.workers.iter().all(|w| w.rank != 1));
    assert!(report.workers.iter().any(|w| w.reassignments >= 1));
}

#[test]
fn process_abort_policy_fails_typed_on_a_real_death() {
    let err = Bsf::new(mc_process())
        .workers(3)
        .engine(process_engine(Some((1, 1))))
        .run()
        .unwrap_err();
    assert!(matches!(err, BsfError::WorkerLost { rank: 1, .. }), "{err}");
}

#[test]
fn process_restart_from_checkpoint_respawns_and_completes() {
    let baseline3 = fresh_threaded(mc_process(), 3);
    let n = baseline3.iterations;
    // Budget more than half the run: generation 2's clone of the killed
    // worker (same argv, fresh budget) survives to the end.
    let budget = n / 2 + 1;
    let cfg = bsf::BsfConfig::with_workers(3).fault(FaultPolicy::RestartFromCheckpoint);
    let report = Bsf::new(mc_process())
        .config(cfg)
        .engine(process_engine(Some((1, budget))))
        .run()
        .unwrap();
    assert_eq!(
        report.param, baseline3.param,
        "restarted run resumes at full K bit-identically"
    );
    assert_eq!(report.iterations, baseline3.iterations);
    assert_eq!(report.losses, vec![1]);
    assert_eq!(report.workers.len(), 3, "generation 2 ran at full strength");
}

// ---------------------------------------------------------------------
// Persistent cluster × real worker death: shrink, don't poison
// ---------------------------------------------------------------------

#[test]
fn cluster_shrinks_on_loss_and_stays_usable_for_a_subsequent_run() {
    let fresh2 = fresh_threaded(mc_process(), 2);

    let cluster = Cluster::spawn(3, mc_worker_argv(Some((2, 1))))
        .program(BSF_BIN)
        .start(&mc_process())
        .unwrap();
    assert_eq!(cluster.alive_workers(), Some(3));

    // Run 1: rank 2 dies after one fold; the run redistributes and
    // completes identically to a fresh 2-worker run.
    let cfg = bsf::BsfConfig::with_workers(3).redistribute_on_loss(1);
    let r1 = Bsf::new(mc_process())
        .config(cfg)
        .engine(cluster.engine())
        .run()
        .unwrap();
    assert_eq!(r1.engine, "cluster");
    assert_eq!(r1.param, fresh2.param);
    assert_eq!(r1.losses, vec![2]);
    assert_eq!(r1.workers.len(), 2, "survivor reports only");

    // The acceptance shape: the pool is SHRUNK, not poisoned — a
    // subsequent run at K-1 reuses the surviving processes.
    assert_eq!(cluster.alive_workers(), Some(2), "pool shrunk to survivors");
    let r2 = Bsf::new(mc_process())
        .workers(2)
        .engine(cluster.engine())
        .run()
        .unwrap();
    assert_eq!(r2.param, fresh2.param, "shrunk cluster matches fresh K-1");
    assert_eq!(r2.losses, Vec::<usize>::new());
    assert_eq!(r2.workers.len(), 2);
    for w2 in &r2.workers {
        let w1 = r1.workers.iter().find(|w| w.rank == w2.rank).unwrap();
        assert_eq!(w1.pid, w2.pid, "run 2 reused run 1's surviving processes");
    }

    // Wrong K on a shrunk pool is a typed config error naming the facts.
    let err = Bsf::new(mc_process())
        .workers(3)
        .engine(cluster.engine())
        .run()
        .unwrap_err();
    assert!(matches!(err, BsfError::Config(_)), "{err}");
    assert!(err.to_string().contains("usable"), "{err}");

    // Teardown tolerates the long-dead rank 2 child.
    cluster.shutdown().unwrap();
}

#[test]
fn cluster_abort_policy_poisons_the_pool() {
    let cluster = Cluster::spawn(2, mc_worker_argv(Some((0, 1))))
        .program(BSF_BIN)
        .start(&mc_process())
        .unwrap();
    let err = Bsf::new(mc_process())
        .workers(2)
        .engine(cluster.engine())
        .run()
        .unwrap_err();
    assert!(matches!(err, BsfError::WorkerLost { rank: 0, .. }), "{err}");
    // An unrecovered loss tears the core down: no further runs, and
    // shutdown reports the teardown.
    let err = Bsf::new(mc_process())
        .workers(2)
        .engine(cluster.engine())
        .run()
        .unwrap_err();
    assert!(matches!(err, BsfError::Config(_)), "{err}");
    assert!(cluster.alive_workers().is_none(), "core gone");
    assert!(cluster.shutdown().is_err(), "nothing left to shut down");
}

#[test]
fn cluster_restart_policy_cannot_respawn_and_fails_typed() {
    // A persistent pool has no spawner to re-create its lost member:
    // the restart relaunch finds the torn-down cluster and fails with a
    // typed config error (use Redistribute on clusters instead).
    let cluster = Cluster::spawn(2, mc_worker_argv(Some((0, 1))))
        .program(BSF_BIN)
        .start(&mc_process())
        .unwrap();
    let cfg = bsf::BsfConfig::with_workers(2).fault(FaultPolicy::RestartFromCheckpoint);
    let err = Bsf::new(mc_process())
        .config(cfg)
        .engine(cluster.engine())
        .run()
        .unwrap_err();
    assert!(matches!(err, BsfError::Config(_)), "{err}");
    let _ = cluster; // dropped: best-effort teardown of the survivors
}

// ---------------------------------------------------------------------
// Mixed: losses recorded on the unified report across engines
// ---------------------------------------------------------------------

#[test]
fn loss_free_runs_report_no_losses() {
    let r = fresh_threaded(mc_threaded(), 3);
    assert!(r.losses.is_empty());
    assert!(r.workers.iter().all(|w| w.reassignments == 0));
}
