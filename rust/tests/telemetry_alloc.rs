//! Hot-path guard: recording an iteration into `RunTelemetry` must not
//! allocate in steady state (the master taps it every iteration). The
//! ring is preallocated and every event payload is scalar, so a clean
//! pass allocates nothing; a deterministic per-call allocation would
//! taint every pass.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use bsf::metrics::telemetry::RunTelemetry;
use bsf::transport::VolumeByTag;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

// SAFETY: defers every operation to `System`; only adds a relaxed
// counter bump on the allocating paths.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_record_iteration_does_not_allocate() {
    let t = Arc::new(RunTelemetry::new());
    t.run_start("threaded", 4);
    // Warm the aggregator: delta state and a first batch of ring slots.
    for i in 1..=64u64 {
        t.record_iteration(i, i as f64 * 0.001, [0.5, 0.25, 0.125, 0.0625], VolumeByTag::default());
    }
    // The test harness's own threads may allocate concurrently, so
    // accept the guard as passed if any single pass over 64 iterations
    // observes zero allocations.
    let mut clean = false;
    for round in 0..10u64 {
        let before = ALLOCS.load(Ordering::Relaxed);
        for i in 0..64u64 {
            let iter = 65 + round * 64 + i;
            t.record_iteration(
                iter,
                iter as f64 * 0.001,
                [0.5, 0.25, 0.125, 0.0625],
                VolumeByTag::default(),
            );
        }
        if ALLOCS.load(Ordering::Relaxed) == before {
            clean = true;
            break;
        }
    }
    assert!(clean, "record_iteration allocated in every measured pass");
}
