//! Schema tests for the `bsf-events/1` event stream: the exact JSONL
//! field names are a public contract (external dashboards parse them),
//! so every variant is golden-tested byte-for-byte and round-tripped
//! through `Json::parse` + `RunEvent::from_json`.

use bsf::metrics::telemetry::{RunEvent, EVENTS_SCHEMA, METRICS_SCHEMA};
use bsf::util::json::Json;

fn round_trip(e: &RunEvent) -> RunEvent {
    let line = e.to_json().compact();
    let parsed = Json::parse(&line).unwrap_or_else(|err| panic!("{line}: {err}"));
    RunEvent::from_json(&parsed).unwrap_or_else(|err| panic!("{line}: {err}"))
}

#[test]
fn schema_constants_are_versioned() {
    assert_eq!(EVENTS_SCHEMA, "bsf-events/1");
    assert_eq!(METRICS_SCHEMA, "bsf-metrics/1");
}

#[test]
fn golden_run_start() {
    let e = RunEvent::RunStart { engine: "threaded".into(), workers: 4 };
    assert_eq!(
        e.to_json().compact(),
        r#"{"schema":"bsf-events/1","type":"run_start","engine":"threaded","workers":4}"#
    );
    assert_eq!(round_trip(&e), e);
}

#[test]
fn golden_iteration_without_prediction() {
    let e = RunEvent::Iteration {
        iter: 3,
        elapsed: 1.5,
        measured: [0.5, 0.25, 0.125, 0.0625],
        predicted: None,
        messages: 10,
        bytes: 640,
    };
    assert_eq!(
        e.to_json().compact(),
        concat!(
            r#"{"schema":"bsf-events/1","type":"iteration","iter":3,"#,
            r#""elapsed_seconds":1.5,"#,
            r#""measured":{"send_order":0.5,"gather":0.25,"master_reduce":0.125,"process":0.0625},"#,
            r#""predicted":null,"messages":10,"bytes":640}"#
        )
    );
    assert_eq!(round_trip(&e), e);
}

#[test]
fn golden_iteration_with_prediction() {
    let e = RunEvent::Iteration {
        iter: 4,
        elapsed: 2.0,
        measured: [0.5, 0.25, 0.125, 0.0625],
        predicted: Some([0.5, 0.5, 0.25, 0.125]),
        messages: 8,
        bytes: 512,
    };
    assert_eq!(
        e.to_json().compact(),
        concat!(
            r#"{"schema":"bsf-events/1","type":"iteration","iter":4,"#,
            r#""elapsed_seconds":2,"#,
            r#""measured":{"send_order":0.5,"gather":0.25,"master_reduce":0.125,"process":0.0625},"#,
            r#""predicted":{"send_order":0.5,"gather":0.5,"master_reduce":0.25,"process":0.125},"#,
            r#""messages":8,"bytes":512}"#
        )
    );
    assert_eq!(round_trip(&e), e);
}

#[test]
fn golden_loss_rejoin_restart() {
    let loss = RunEvent::Loss { iter: 7, rank: 1 };
    assert_eq!(
        loss.to_json().compact(),
        r#"{"schema":"bsf-events/1","type":"loss","iter":7,"rank":1}"#
    );
    assert_eq!(round_trip(&loss), loss);

    let rejoin = RunEvent::Rejoin { iter: 9, rank: 1 };
    assert_eq!(
        rejoin.to_json().compact(),
        r#"{"schema":"bsf-events/1","type":"rejoin","iter":9,"rank":1}"#
    );
    assert_eq!(round_trip(&rejoin), rejoin);

    let restart = RunEvent::Restart { generation: 1, iter: 4, rank: 2 };
    assert_eq!(
        restart.to_json().compact(),
        r#"{"schema":"bsf-events/1","type":"restart","generation":1,"iter":4,"rank":2}"#
    );
    assert_eq!(round_trip(&restart), restart);
}

#[test]
fn golden_run_end() {
    let e = RunEvent::RunEnd { iter: 12, elapsed: 2.5 };
    assert_eq!(
        e.to_json().compact(),
        r#"{"schema":"bsf-events/1","type":"run_end","iter":12,"elapsed_seconds":2.5}"#
    );
    assert_eq!(round_trip(&e), e);
}

#[test]
fn iteration_parses_with_predicted_field_absent() {
    // Forward compatibility: a stream written before a cost model was
    // attached may omit `predicted` entirely, not just null it.
    let line = concat!(
        r#"{"schema":"bsf-events/1","type":"iteration","iter":5,"#,
        r#""elapsed_seconds":0.5,"#,
        r#""measured":{"send_order":0.5,"gather":0.25,"master_reduce":0.125,"process":0.0625},"#,
        r#""messages":2,"bytes":64}"#
    );
    let e = RunEvent::from_json(&Json::parse(line).unwrap()).unwrap();
    match e {
        RunEvent::Iteration { iter: 5, predicted: None, messages: 2, bytes: 64, .. } => {}
        other => panic!("unexpected parse: {other:?}"),
    }
}

#[test]
fn from_json_rejects_bad_documents() {
    let wrong_schema = r#"{"schema":"bsf-events/2","type":"run_end","iter":1,"elapsed_seconds":1}"#;
    let err = RunEvent::from_json(&Json::parse(wrong_schema).unwrap()).unwrap_err();
    assert!(err.contains("schema"), "{err}");

    let unknown_type = r#"{"schema":"bsf-events/1","type":"comet","iter":1}"#;
    let err = RunEvent::from_json(&Json::parse(unknown_type).unwrap()).unwrap_err();
    assert!(err.contains("unknown event type"), "{err}");

    let missing_field = r#"{"schema":"bsf-events/1","type":"loss","iter":1}"#;
    let err = RunEvent::from_json(&Json::parse(missing_field).unwrap()).unwrap_err();
    assert!(err.contains("rank"), "{err}");
}
