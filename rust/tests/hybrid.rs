//! Hybrid two-level parallelism (the paper's MPI × OpenMP grid):
//! session-level equivalence of the intra-worker tier across every
//! problem, and the panic contract of the chunk pool under both the
//! thread transport and real TCP between processes.
//!
//! Bit-exactness scope: with the *same* (K, T) the chunk grid and the
//! chunk-order merge are identical on every engine, so results are
//! bit-identical (asserted here process-vs-threaded, and in CI).
//! Across *different* T the fold is reassociated at chunk boundaries,
//! so float-summing problems agree to tolerance while exactly
//! associative reduces (integer sums, concatenation) stay bit-equal —
//! the same contract the repo applies across different K.

use std::net::TcpListener;
use std::time::Duration;

use bsf::problems::apex::ApexProblem;
use bsf::problems::cimmino::CimminoProblem;
use bsf::problems::gravity::GravityProblem;
use bsf::problems::jacobi::JacobiProblem;
use bsf::problems::jacobi_map::JacobiMapProblem;
use bsf::problems::lpp::LppProblem;
use bsf::problems::montecarlo::MonteCarloProblem;
use bsf::skeleton::master::run_master;
use bsf::skeleton::problem::{IterCtx, MapCtx, StepDecision};
use bsf::skeleton::process::run_process_worker;
use bsf::skeleton::{BsfProblem, FusedNativeBackend, RunReport};
use bsf::transport::tcp::{accept_workers, ProblemSig};
use bsf::util::codec::Codec;
use bsf::{Bsf, BsfConfig, BsfError, ProcessEngine, SerialEngine, ThreadedEngine};

const BSF_BIN: &str = env!("CARGO_BIN_EXE_bsf");

fn run_threaded<P: BsfProblem>(problem: P, workers: usize, threads: usize) -> RunReport<P::Param> {
    Bsf::new(problem)
        .workers(workers)
        .threads_per_worker(threads)
        .engine(ThreadedEngine)
        .run()
        .unwrap()
}

/// T=1 vs T=3 at the same K: iteration counts must match exactly; the
/// caller supplies the parameter comparison appropriate to its ⊕.
fn hybrid_vs_flat<P: BsfProblem>(
    mk: impl Fn() -> P,
    check: impl Fn(&P::Param, &P::Param),
) {
    let flat = run_threaded(mk(), 2, 1);
    let hybrid = run_threaded(mk(), 2, 3);
    assert_eq!(flat.iterations, hybrid.iterations, "same stop condition, same count");
    assert!(hybrid.workers.iter().all(|w| w.threads == 3));
    assert!(flat.workers.iter().all(|w| w.threads == 1));
    check(&flat.param, &hybrid.param);
}

fn close(a: &[f64], b: &[f64], tol: f64) {
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(b) {
        assert!((x - y).abs() < tol, "{x} vs {y}");
    }
}

#[test]
fn hybrid_tier_preserves_results_for_every_problem() {
    // Float vector sums: reassociated at chunk boundaries → tolerance.
    hybrid_vs_flat(|| JacobiProblem::random(30, 1e-16, 4).0, |a, b| close(a, b, 1e-9));
    hybrid_vs_flat(|| JacobiMapProblem::random(30, 1e-16, 4).0, |a, b| close(a, b, 1e-9));
    hybrid_vs_flat(|| CimminoProblem::random(24, 24, 1e-10, 4).0, |a, b| close(a, b, 1e-9));
    hybrid_vs_flat(|| LppProblem::random(48, 12, 4), |a, b| close(a, b, 1e-9));
    hybrid_vs_flat(
        || ApexProblem::random(48, 12, 4),
        |a, b| {
            close(&a.0, &b.0, 1e-9);
            assert!((a.1 - b.1).abs() < 1e-9);
        },
    );
    // Exactly associative reduces: bit-identical across thread counts.
    hybrid_vs_flat(
        || MonteCarloProblem::new(24, 500, 1e-3),
        |a, b| assert_eq!(a.to_bytes(), b.to_bytes(), "integer sums are exact"),
    );
    hybrid_vs_flat(
        || GravityProblem::random(12, 1e-3, 4, 4),
        |a, b| assert_eq!(a.to_bytes(), b.to_bytes(), "concatenation ⊕ is exact"),
    );
}

#[test]
fn serial_engine_honors_the_hybrid_tier() {
    let (p1, _) = JacobiProblem::random(40, 1e-14, 9);
    let (pt, _) = JacobiProblem::random(40, 1e-14, 9);
    let r1 = Bsf::new(p1).workers(1).engine(SerialEngine).run().unwrap();
    let rt = Bsf::new(pt)
        .workers(1)
        .threads_per_worker(4)
        .engine(SerialEngine)
        .run()
        .unwrap();
    assert_eq!(r1.iterations, rt.iterations);
    assert_eq!(rt.workers[0].threads, 4);
    assert!(rt.workers[0].max_chunk_seconds > 0.0, "chunk timing recorded");
    close(&r1.param, &rt.param, 1e-9);
    // The hybrid summary speaks only for hybrid runs.
    assert_eq!(r1.hybrid_summary(), "");
    assert!(rt.hybrid_summary().contains("threads/worker=4"));
}

/// The acceptance grid: K=2 worker OS processes × T=2 map threads each
/// must be **bit-identical** to the threaded engine at the same (K, T)
/// — same partition, same chunk grid, chunk-order merge.
#[test]
fn hybrid_process_engine_matches_hybrid_threaded_bit_exactly() {
    let n = 48;
    let rt = run_threaded(JacobiProblem::random(n, 1e-12, 7).0, 2, 2);

    let (pp, _) = JacobiProblem::random(n, 1e-12, 7);
    let argv: Vec<String> = [
        "worker", "--problem", "jacobi", "--n", "48", "--seed", "7", "--eps", "1e-12",
        "--threads-per-worker", "2",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    let engine = ProcessEngine::spawn_args(argv).program(BSF_BIN);
    let rp = Bsf::new(pp)
        .workers(2)
        .threads_per_worker(2)
        .engine(engine)
        .run()
        .unwrap();

    assert_eq!(rp.engine, "process");
    assert_eq!(rp.iterations, rt.iterations);
    assert_eq!(rp.param, rt.param, "same (K, T) grid must be bit-identical");
    // The thread-level breakdown crossed the process boundary.
    assert_eq!(rp.workers.len(), 2);
    assert!(rp.workers.iter().all(|w| w.threads == 2));
    assert!(rp.workers.iter().any(|w| w.max_chunk_seconds > 0.0));
    assert!(rp.hybrid_summary().contains("threads/worker=2"));
}

// ------------------------------------------------------------------
// Panic contract: a panic inside a *pool thread* must surface as
// WorkerPanic (never a hang) under both transports.

/// Map panics on one specific element, so exactly one chunk of one
/// worker's pool dies while the sibling chunks complete.
struct PanicProblem {
    n: usize,
    poison: usize,
}

impl BsfProblem for PanicProblem {
    type Param = u64;
    type MapElem = usize;
    type ReduceElem = u64;

    fn list_size(&self) -> usize {
        self.n
    }

    fn map_list_elem(&self, i: usize) -> usize {
        i
    }

    fn init_parameter(&self) -> u64 {
        0
    }

    fn map_f(&self, elem: &usize, _param: &u64, _ctx: &MapCtx) -> Option<u64> {
        assert!(*elem != self.poison, "poisoned element {elem} reached map_f");
        Some(1)
    }

    fn reduce_f(&self, x: &u64, y: &u64, _job: usize) -> u64 {
        x + y
    }

    fn process_results(
        &self,
        _reduce_result: Option<&u64>,
        _reduce_counter: u64,
        _param: &mut u64,
        _ctx: &IterCtx,
    ) -> StepDecision {
        StepDecision { next_job: 0, exit: true }
    }
}

#[test]
fn pool_thread_panic_is_worker_panic_on_the_thread_transport() {
    // n=8, K=2 → worker 1 holds 4..8; T=2 chunks it as [4,6) [6,8), so
    // the poison at 5 panics inside a pool thread, not the worker loop.
    let err = Bsf::new(PanicProblem { n: 8, poison: 5 })
        .workers(2)
        .threads_per_worker(2)
        .engine(ThreadedEngine)
        .run()
        .unwrap_err();
    assert!(matches!(err, BsfError::WorkerPanic { rank: 1 }), "{err}");
}

#[test]
fn pool_thread_panic_is_worker_panic_on_the_serial_engine() {
    let err = Bsf::new(PanicProblem { n: 8, poison: 3 })
        .workers(1)
        .threads_per_worker(4)
        .engine(SerialEngine)
        .run()
        .unwrap_err();
    assert!(matches!(err, BsfError::WorkerPanic { rank: 0 }), "{err}");
}

#[test]
fn pool_thread_panic_is_worker_panic_over_real_tcp() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let problem = PanicProblem { n: 8, poison: 3 };
    let sig = ProblemSig {
        list_size: problem.list_size() as u64,
        job_count: problem.job_count() as u64,
    };
    let cfg = BsfConfig::with_workers(1).threads_per_worker(2);

    // The worker endpoint in a real second thread over real TCP, driving
    // the same guarded loop a worker process runs.
    let worker_cfg = cfg.clone();
    let worker = std::thread::spawn(move || {
        let problem = PanicProblem { n: 8, poison: 3 };
        run_process_worker(&problem, &FusedNativeBackend, &addr, 0, &worker_cfg)
    });

    let master_ep = accept_workers(listener, 1, sig, Duration::from_secs(30), || Ok(())).unwrap();
    // The gather must observe Tag::Abort and surface WorkerPanic —
    // never block on a fold that will not come.
    let err = run_master(&problem, &master_ep, &cfg).unwrap_err();
    assert!(matches!(err, BsfError::WorkerPanic { rank: 0 }), "{err}");

    // The worker side reports the same typed error (its endpoint sent
    // Abort before dying).
    let worker_result = worker.join().expect("worker thread itself must not die");
    assert!(
        matches!(worker_result, Err(BsfError::WorkerPanic { rank: 0 })),
        "{worker_result:?}"
    );
}

#[test]
fn bench_harness_quick_grid_runs_hybrid_cases_through_real_processes() {
    use bsf::bench::harness::{compare, grid, run_case, BenchSuite};

    // The hybrid process point of the CI grid, end to end with real
    // worker processes, feeding the comparison path.
    let case = grid("quick")
        .unwrap()
        .into_iter()
        .find(|c| c.engine == "process" && c.threads_per_worker > 1)
        .expect("quick grid has a hybrid process case");
    let record = run_case(&case, Some(std::path::Path::new(BSF_BIN))).unwrap();
    assert!(record.iterations > 0);

    let suite = BenchSuite {
        label: "test".into(),
        mode: "quick".into(),
        bootstrap: false,
        records: vec![record.clone()],
    };
    let round = BenchSuite::parse(&suite.to_json()).unwrap();
    assert_eq!(round.records[0].iterations, record.iterations);
    // Identical suites always pass their own gate.
    let report = compare(&suite, &round, 0.25).unwrap();
    assert!(report.contains("ok"), "{report}");

    // The committed bootstrap baseline accepts a fresh quick run's
    // record for its case (coverage check only).
    let baseline_text =
        std::fs::read_to_string(concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_baseline.json"))
            .expect("committed BENCH_baseline.json");
    let baseline = BenchSuite::parse(&baseline_text).unwrap();
    assert!(baseline.bootstrap);
    assert!(baseline.records.iter().any(|r| r.case.key() == record.case.key()));
}

#[test]
fn simulator_charges_the_intra_worker_tier() {
    use bsf::costmodel::ClusterProfile;
    use bsf::simcluster::SimConfig;
    use bsf::skeleton::SimulatedEngine;

    let vt = |threads: usize, fork_join: f64| {
        let (p, _) = JacobiProblem::random(64, 1e-30, 7);
        let sim = SimConfig::new(ClusterProfile::ideal())
            .per_element(1e-6)
            .fork_join(fork_join);
        Bsf::new(p)
            .workers(2)
            .threads_per_worker(threads)
            .max_iter(4)
            .engine(SimulatedEngine::with_config(sim))
            .run()
            .unwrap()
            .elapsed
    };
    // The deterministic model charges the parallel critical path:
    // ceil(32/4)·t_elem < 32·t_elem per worker per iteration.
    let flat = vt(1, 0.0);
    let hybrid = vt(4, 0.0);
    assert!(
        hybrid < flat,
        "T=4 critical path must shrink virtual time: {hybrid} vs {flat}"
    );
    // ... and the fork/join term pushes it back up (the OpenMP
    // ablation's overhead corner).
    let costly = vt(4, 1e-2);
    assert!(costly > hybrid, "fork/join overhead must cost virtual time");
}

#[test]
fn process_worker_cli_accepts_threads_per_worker() {
    // `bsf run --engine process --threads-per-worker 2` through the real
    // binary: the child argv must round-trip the hybrid flag (a drifted
    // worker config would change the chunk grid and break bit-equality
    // with the threaded engine, which the run below asserts via CI too).
    let out = std::process::Command::new(BSF_BIN)
        .args([
            "run", "jacobi", "--n", "64", "--engine", "process", "--workers", "2",
            "--threads-per-worker", "2",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "hybrid process run failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stdout.contains("engine=process"), "{stdout}");
    // The hybrid diagnostic line lives on stderr (stdout is results-only).
    assert!(stderr.contains("hybrid: threads/worker=2"), "{stderr}");
}
