//! Integration: the unified `Bsf` session API — one entry point driving
//! threaded, serial and simulated execution for the same problem
//! definitions, with typed errors end to end.

use std::sync::Arc;

use bsf::costmodel::ClusterProfile;
use bsf::problems::apex::ApexProblem;
use bsf::problems::cimmino::CimminoProblem;
use bsf::problems::gravity::GravityProblem;
use bsf::problems::jacobi::JacobiProblem;
use bsf::problems::jacobi_map::JacobiMapProblem;
use bsf::problems::lpp::LppProblem;
use bsf::problems::montecarlo::MonteCarloProblem;
use bsf::skeleton::{
    Bsf, BsfConfig, Clock, SerialEngine, SimulatedEngine, ThreadedEngine,
};
use bsf::BsfError;

/// One session API drives all three engines for every problem: run each
/// problem at K=1 under serial/threaded/simulated and compare numerics.
#[test]
fn all_engines_agree_for_all_problems() {
    fn check<P, F>(mk: F, name: &str)
    where
        P: bsf::BsfProblem,
        P::Param: PartialEq + std::fmt::Debug,
        F: Fn() -> P,
    {
        let cfg = || BsfConfig::with_workers(1).max_iter(200);
        let rs = Bsf::new(mk()).config(cfg()).engine(SerialEngine).run().unwrap();
        let rt = Bsf::new(mk()).config(cfg()).engine(ThreadedEngine).run().unwrap();
        let rv = Bsf::new(mk())
            .config(cfg())
            .engine(SimulatedEngine::new(ClusterProfile::infiniband()))
            .run()
            .unwrap();
        assert_eq!(rs.iterations, rt.iterations, "{name}: serial vs threaded");
        assert_eq!(rs.iterations, rv.iterations, "{name}: serial vs simulated");
        assert_eq!(rs.param, rt.param, "{name}: serial vs threaded numerics");
        assert_eq!(rs.param, rv.param, "{name}: serial vs simulated numerics");
        assert_eq!(rs.clock, Clock::Real);
        assert_eq!(rv.clock, Clock::Virtual);
    }

    check(|| JacobiProblem::random(24, 1e-14, 901).0, "jacobi");
    check(|| JacobiMapProblem::random(24, 1e-14, 902).0, "jacobi-map");
    check(|| CimminoProblem::random(24, 8, 1e-12, 903).0, "cimmino");
    check(|| GravityProblem::random(12, 1e-3, 15, 904), "gravity");
    check(
        || {
            let mut p = MonteCarloProblem::new(6, 300, 1e-12);
            p.max_rounds = 4;
            p
        },
        "montecarlo",
    );
    check(|| LppProblem::random(30, 4, 905), "lpp");
    check(|| ApexProblem::random(20, 3, 906), "apex");
}

#[test]
fn simulated_engine_reports_virtual_and_real_time() {
    let (p, _) = JacobiProblem::random(32, 1e-30, 907);
    let r = Bsf::new(p)
        .config(BsfConfig::with_workers(8).max_iter(10))
        .engine(SimulatedEngine::new(ClusterProfile::gigabit()))
        .run()
        .unwrap();
    assert_eq!(r.clock, Clock::Virtual);
    assert_eq!(r.engine, "simulated");
    assert!(r.elapsed > 0.0, "virtual seconds");
    assert!(r.wall_seconds > 0.0, "real seconds");
    assert!(r.messages > 0 && r.bytes > 0, "simulated transport accounted");
    assert_eq!(r.workers.len(), 8, "per-worker summaries in the unified report");
    assert!(r.phases.total() > 0.0);
    assert!(r.summary().contains("virtual="));
}

#[test]
fn threaded_report_has_unified_shape() {
    let (p, _) = JacobiProblem::random(32, 1e-16, 908);
    let r = Bsf::new(p).workers(3).engine(ThreadedEngine).run().unwrap();
    assert_eq!(r.clock, Clock::Real);
    assert_eq!(r.engine, "threaded");
    assert_eq!(r.workers.len(), 3);
    assert!((r.elapsed - r.wall_seconds).abs() < 1e-12);
    assert!(r.mean_worker_map_secs_per_iter() >= 0.0);
}

#[test]
fn serial_fast_path_skips_the_transport() {
    let (p, _) = JacobiProblem::random(32, 1e-16, 909);
    let r = Bsf::new(p).workers(1).run().unwrap(); // Auto → serial at K=1
    assert_eq!(r.engine, "serial");
    assert_eq!(r.messages, 0);
    assert_eq!(r.bytes, 0);
    assert_eq!(r.workers.len(), 1);
    assert_eq!(r.workers[0].sublist_length, 32);
}

#[test]
fn auto_engine_picks_threaded_beyond_one_worker() {
    let (p, _) = JacobiProblem::random(16, 1e-12, 910);
    let r = Bsf::new(p).workers(2).run().unwrap();
    assert_eq!(r.engine, "threaded");
    assert!(r.messages > 0);
}

#[test]
fn config_errors_are_typed_for_every_engine() {
    let mk = || JacobiProblem::random(8, 1e-12, 911).0;
    let zero_t = Bsf::new(mk()).workers(0).engine(ThreadedEngine).run().unwrap_err();
    let zero_v = Bsf::new(mk())
        .workers(0)
        .engine(SimulatedEngine::new(ClusterProfile::ideal()))
        .run()
        .unwrap_err();
    let multi_serial = Bsf::new(mk()).workers(3).engine(SerialEngine).run().unwrap_err();
    for err in [zero_t, zero_v, multi_serial] {
        assert!(matches!(err, BsfError::Config(_)), "{err}");
    }
}

#[test]
fn sessions_share_problems_through_arc() {
    let p = Arc::new(LppProblem::random(40, 5, 912));
    let r = Bsf::from_arc(Arc::clone(&p))
        .workers(4)
        .max_iter(100_000)
        .run()
        .unwrap();
    // The caller-side handle still sees the problem after the run.
    assert_eq!(p.violations(&r.param), 0);
}

/// A problem whose map panics on one element: every engine must surface
/// a typed `WorkerPanic` instead of deadlocking the gather or unwinding
/// through `run()`.
struct PanickingMap;

impl bsf::BsfProblem for PanickingMap {
    type Param = u64;
    type MapElem = usize;
    type ReduceElem = u64;

    fn list_size(&self) -> usize {
        8
    }
    fn map_list_elem(&self, i: usize) -> usize {
        i
    }
    fn init_parameter(&self) -> u64 {
        0
    }
    fn map_f(
        &self,
        &i: &usize,
        _p: &u64,
        _ctx: &bsf::skeleton::MapCtx,
    ) -> Option<u64> {
        if i == 5 {
            panic!("user map code exploded");
        }
        Some(1)
    }
    fn reduce_f(&self, x: &u64, y: &u64, _job: usize) -> u64 {
        x + y
    }
    fn process_results(
        &self,
        _r: Option<&u64>,
        _c: u64,
        _p: &mut u64,
        _ctx: &bsf::skeleton::problem::IterCtx,
    ) -> bsf::skeleton::StepDecision {
        bsf::skeleton::StepDecision::exit()
    }
}

#[test]
fn worker_panic_is_a_typed_error_not_a_deadlock() {
    for k in [1usize, 2, 4] {
        let err = Bsf::new(PanickingMap)
            .workers(k)
            .engine(ThreadedEngine)
            .run()
            .unwrap_err();
        assert!(matches!(err, BsfError::WorkerPanic { .. }), "K={k}: {err}");
    }
    let err = Bsf::new(PanickingMap)
        .workers(1)
        .engine(SerialEngine)
        .run()
        .unwrap_err();
    assert!(matches!(err, BsfError::WorkerPanic { rank: 0 }), "{err}");
    let err = Bsf::new(PanickingMap)
        .workers(3)
        .engine(SimulatedEngine::new(ClusterProfile::ideal()))
        .run()
        .unwrap_err();
    assert!(matches!(err, BsfError::WorkerPanic { .. }), "{err}");
}

#[test]
fn errors_format_like_thiserror() {
    let (p, _) = JacobiProblem::random(8, 1e-12, 913);
    let err = Bsf::new(p).workers(0).run().unwrap_err();
    let msg = err.to_string();
    assert!(msg.starts_with("configuration error:"), "{msg}");
    // And they are real std errors (boxable, source-chained).
    let boxed: Box<dyn std::error::Error> = Box::new(err);
    assert!(boxed.source().is_none());
}
