//! Integration tests for the `bsf verify` model checker — through the
//! same public API the CLI uses.
//!
//! Three claims are proven here:
//!
//! 1. A healthy world passes: every explored schedule (fault-free and
//!    fault-injected) completes with zero violations.
//! 2. The checker has teeth: seeding the PR 5 duplicate-fold bug via
//!    [`Mutation::DuplicateFold`] makes the same exploration report
//!    violations.
//! 3. The end-of-run drain assertion catches the one shape the master's
//!    in-protocol guards cannot: a fold that arrives *after* the exit
//!    handshake (the regression the checker's orphan invariant encodes).

use bsf::problems::jacobi::JacobiProblem;
use bsf::problems::pagerank::PageRankProblem;
use bsf::skeleton::master::run_master;
use bsf::transport::{build_thread_transport, debug_assert_drained, Communicator, Tag};
use bsf::util::codec::Codec;
use bsf::verify::{run_verify, Mutation, VerifyConfig};
use bsf::BsfConfig;

/// A small world the checker can exhaust quickly: eps far below reach,
/// so every schedule runs exactly `max_iter` iterations.
fn small_cfg() -> VerifyConfig {
    VerifyConfig {
        workers: 2,
        max_iter: 3,
        max_schedules: 2_000,
        faults: true,
        mutation: Mutation::None,
    }
}

#[test]
fn healthy_world_verifies_clean() {
    let report = run_verify(|| JacobiProblem::random(8, 1e-30, 7).0, &small_cfg());
    assert!(
        report.ok(),
        "healthy world must verify clean, got violations:\n{}",
        report.violations.join("\n")
    );
    assert_eq!(report.reference_iterations, 3, "eps must be unreachable");
    // One contested gather decision per iteration → 2^3 base schedules.
    assert_eq!(report.base_schedules, 8);
    assert!(!report.truncated);
    // Every fault policy must actually have lost a worker at least once
    // (round-0 injection always fires), or the fault legs proved nothing.
    assert!(report.abort_losses >= 1, "no Abort loss fired");
    assert!(report.redistribute_losses >= 1, "no Redistribute loss fired");
    assert!(report.restart_losses >= 1, "no RestartFromCheckpoint loss fired");
    assert!(report.fault_schedules > 0);
    // Jacobi's element-wise disjoint-support reduce is split-invariant,
    // so the strong Redistribute byte-equality check was enforced.
    assert!(report.split_invariant, "jacobi reduce must be split-invariant");
}

#[test]
fn pagerank_world_verifies_clean() {
    // The variable-length wire leg: pagerank's reduce element is a
    // sparse, length-prefixed `Vec<(u32, i64)>`, so every explored
    // schedule carries frames whose payload size depends on which
    // blocks folded where — a shape no fixed-size problem puts on the
    // wire. The same invariants must hold: no deadlock, no misroute,
    // no orphan, and bit-identical results across schedules (the
    // fixed-point contributions make any fold grouping exact).
    let report = run_verify(|| PageRankProblem::new(8, 2, 1e-30, 7), &small_cfg());
    assert!(
        report.ok(),
        "pagerank world must verify clean, got violations:\n{}",
        report.violations.join("\n")
    );
    assert_eq!(report.reference_iterations, 3, "eps must be unreachable");
    assert_eq!(report.base_schedules, 8);
    assert!(report.fault_schedules > 0);
    assert!(report.redistribute_losses >= 1, "no Redistribute loss fired");
}

#[test]
fn seeded_duplicate_fold_is_caught() {
    // Same world, same exploration — but worker 0 double-sends its first
    // fold (the PR 5 bug class). The checker MUST flag it; if this test
    // fails, the checker is decorative.
    let vcfg = VerifyConfig { mutation: Mutation::DuplicateFold, ..small_cfg() };
    let report = run_verify(|| JacobiProblem::random(8, 1e-30, 7).0, &vcfg);
    assert!(
        !report.ok(),
        "checker failed to flag the seeded duplicate-fold mutation"
    );
    assert!(report.base_schedules >= 1);
}

#[test]
fn late_fold_after_exit_is_an_undrained_orphan() {
    // The drain regression behind invariant 3: a rogue worker re-sends
    // its final fold AFTER acknowledging exit=true. Every in-protocol
    // sweep has already run by then, so `run_master` succeeds — only the
    // end-of-run drain check can see the stray message.
    let mut eps = build_thread_transport(1);
    let master = eps.pop().unwrap();
    let w0 = eps.pop().unwrap();
    let (p, _) = JacobiProblem::random(8, 1e-12, 11);
    let cfg = BsfConfig::with_workers(1).max_iter(1);
    // The gate makes "after" deterministic: the rogue's second fold is
    // held until run_master has returned (the master's final stray-fold
    // sweep runs just after the exit broadcast, so an ungated send
    // could still land in time to be caught there).
    let (gate_tx, gate_rx) = std::sync::mpsc::channel::<()>();
    let rogue = std::thread::spawn(move || {
        let _order = w0.recv(1, Tag::Order).unwrap();
        let fold = (Some(vec![0.0f64; 8]), 1u64).to_bytes();
        w0.send(1, Tag::Fold, fold.clone()).unwrap();
        let ex = w0.recv(1, Tag::Exit).unwrap();
        assert!(bool::from_bytes(&ex.payload), "max_iter=1 run must stop");
        gate_rx.recv().unwrap();
        // The late duplicate: sent after the shutdown handshake, so no
        // gather and no stray-fold sweep will ever consume it.
        w0.send(1, Tag::Fold, fold).unwrap();
    });
    let outcome = run_master(&p, &master, &cfg).unwrap();
    assert_eq!(outcome.iterations, 1);
    gate_tx.send(()).unwrap();
    rogue.join().unwrap();

    // The orphan is visible to the inspection API in every build...
    let undrained = master.undrained();
    assert_eq!(undrained, vec![(0, Tag::Fold)], "late fold must be undrained");
    // ...and fatal under the debug drain assertion.
    if cfg!(debug_assertions) {
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            debug_assert_drained(&master, &[], "verify regression: late fold");
        }));
        assert!(caught.is_err(), "debug_assert_drained must flag the late fold");
    }
}
