//! Integration: the PJRT runtime executing the AOT Pallas/JAX artifacts,
//! and the XLA-backed worker map inside full skeleton runs.
//!
//! These tests need `artifacts/` (run `make artifacts`); they are skipped
//! with a message when it is absent so `cargo test` works standalone.

use std::sync::Arc;

use bsf::problems::cimmino::{CimminoBackend, CimminoProblem};
use bsf::problems::gravity::{GravityBackend, GravityProblem};
use bsf::problems::jacobi::{JacobiProblem, MapBackend};
use bsf::problems::jacobi_map::{JacobiMapProblem, MapMapBackend};
use bsf::runtime::service::XlaService;
use bsf::runtime::XlaRuntime;
use bsf::skeleton::{run_threaded, BsfConfig};
use bsf::util::mat::dist2;

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("BSF_ARTIFACTS").unwrap_or_else(|_| {
        // tests run from the crate root
        "artifacts".into()
    });
    if std::path::Path::new(&dir).join("manifest.tsv").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {dir}; run `make artifacts`");
        None
    }
}

#[test]
fn manifest_loads_and_lists_all_kinds() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = XlaRuntime::open(&dir).unwrap();
    for kind in ["jacobi", "jacobi_map", "cimmino", "gravity"] {
        assert!(
            rt.names().iter().any(|n| n.starts_with(kind)),
            "missing {kind} artifacts"
        );
    }
}

#[test]
fn best_chunk_picks_smallest_fitting() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = XlaRuntime::open(&dir).unwrap();
    let m = rt.best_chunk("jacobi", 64, 10).expect("fits in c=16");
    assert_eq!(m.c, 16);
    let m = rt.best_chunk("jacobi", 64, 17).expect("fits in c=64");
    assert_eq!(m.c, 64);
    assert!(rt.best_chunk("jacobi", 64, 65).is_none());
}

#[test]
fn jacobi_artifact_matches_native_matvec() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = XlaRuntime::open(&dir).unwrap();
    // jacobi_n64_c16: (64,16) @ (16,) -> (64,)
    let n = 64;
    let c = 16;
    let cols: Vec<f32> = (0..n * c).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
    let x: Vec<f32> = (0..c).map(|j| (j as f32 - 8.0) * 0.25).collect();
    let out = rt
        .execute_f32("jacobi_n64_c16", &[(&cols, &[n as i64, c as i64]), (&x, &[c as i64])])
        .unwrap();
    assert_eq!(out.len(), n);
    for i in 0..n {
        let want: f32 = (0..c).map(|j| cols[i * c + j] * x[j]).sum();
        assert!((out[i] - want).abs() < 1e-4, "row {i}: {} vs {want}", out[i]);
    }
}

#[test]
fn executable_cache_reuses_compilation() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = XlaRuntime::open(&dir).unwrap();
    let cols = vec![0.5f32; 64 * 16];
    let x = vec![1.0f32; 16];
    let t0 = std::time::Instant::now();
    let _ = rt
        .execute_f32("jacobi_n64_c16", &[(&cols, &[64, 16]), (&x, &[16])])
        .unwrap();
    let first = t0.elapsed();
    let t0 = std::time::Instant::now();
    for _ in 0..5 {
        let _ = rt
            .execute_f32("jacobi_n64_c16", &[(&cols, &[64, 16]), (&x, &[16])])
            .unwrap();
    }
    let warm = t0.elapsed() / 5;
    assert!(warm < first, "warm {warm:?} should beat cold {first:?}");
}

#[test]
fn xla_backed_jacobi_solves_like_native() {
    let Some(dir) = artifacts_dir() else { return };
    let service = XlaService::start(&dir).unwrap();
    // n must be an AOT dimension (64) for the XLA path to engage.
    let (native, x_star) = JacobiProblem::random(64, 1e-10, 401);
    let (xla_p, _) = JacobiProblem::random(64, 1e-10, 401);
    let xla_p = xla_p.with_backend(MapBackend::Xla(service.handle()));
    let rn = run_threaded(Arc::new(native), &BsfConfig::with_workers(4));
    let rx = run_threaded(Arc::new(xla_p), &BsfConfig::with_workers(4));
    // f32 kernel vs f64 native: same fixed point to f32 accuracy.
    assert!(dist2(&rx.param, &x_star) < 1e-4, "dist² {}", dist2(&rx.param, &x_star));
    assert!(dist2(&rn.param, &rx.param) < 1e-4);
}

#[test]
fn xla_backed_jacobi_map_solves() {
    let Some(dir) = artifacts_dir() else { return };
    let service = XlaService::start(&dir).unwrap();
    let (p, x_star) = JacobiMapProblem::random(64, 1e-10, 402);
    let p = p.with_backend(MapMapBackend::Xla(service.handle()));
    let r = run_threaded(Arc::new(p), &BsfConfig::with_workers(4));
    assert!(dist2(&r.param, &x_star) < 1e-4);
}

#[test]
fn xla_backed_cimmino_converges() {
    let Some(dir) = artifacts_dir() else { return };
    let service = XlaService::start(&dir).unwrap();
    let (p, _) = CimminoProblem::random(64, 64, 1e-10, 403);
    let p = Arc::new(p.with_backend(CimminoBackend::Xla(service.handle())));
    let r0 = p.residual2(&vec![0.0; 64]);
    let r = run_threaded(Arc::clone(&p), &BsfConfig::with_workers(4).max_iter(20_000));
    assert!(p.residual2(&r.param) < r0 * 1e-4);
}

#[test]
fn xla_backed_gravity_matches_native_trajectory() {
    let Some(dir) = artifacts_dir() else { return };
    let service = XlaService::start(&dir).unwrap();
    let native = GravityProblem::random(64, 1e-3, 5, 404);
    let xla_p = GravityProblem::random(64, 1e-3, 5, 404)
        .with_backend(GravityBackend::Xla(service.handle()));
    let rn = run_threaded(Arc::new(native), &BsfConfig::with_workers(4));
    let rx = run_threaded(Arc::new(xla_p), &BsfConfig::with_workers(4));
    for (a, b) in rn.param.iter().zip(&rx.param) {
        assert!((a - b).abs() < 1e-3, "{a} vs {b}");
    }
}

#[test]
fn service_handles_work_from_many_threads() {
    let Some(dir) = artifacts_dir() else { return };
    let service = XlaService::start(&dir).unwrap();
    let handles: Vec<_> = (0..8)
        .map(|t| {
            let h = service.handle();
            std::thread::spawn(move || {
                let cols = vec![t as f32; 64 * 16];
                let x = vec![1.0f32; 16];
                let out = h
                    .execute_f32(
                        "jacobi_n64_c16",
                        vec![(cols, vec![64, 16]), (x, vec![16])],
                    )
                    .unwrap();
                assert!((out[0] - 16.0 * t as f32).abs() < 1e-3);
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}
