//! Integration: the artifact registry, the PJRT service and the generic
//! XLA map backend.
//!
//! Registry/service/fallback tests run everywhere (they need no real
//! backend). Execution tests additionally need `artifacts/` (run
//! `make artifacts`) *and* a linked PJRT binding; they are skipped with a
//! message otherwise so `cargo test` works standalone.

use std::sync::Arc;

use bsf::problems::cimmino::CimminoProblem;
use bsf::problems::gravity::GravityProblem;
use bsf::problems::jacobi::JacobiProblem;
use bsf::problems::jacobi_map::JacobiMapProblem;
use bsf::runtime::backend::XlaMapBackend;
use bsf::runtime::service::XlaService;
use bsf::runtime::XlaRuntime;
use bsf::skeleton::Bsf;
use bsf::util::mat::dist2;
use bsf::BsfError;

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("BSF_ARTIFACTS").unwrap_or_else(|_| {
        // tests run from the crate root
        "artifacts".into()
    });
    if std::path::Path::new(&dir).join("manifest.tsv").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: no artifacts at {dir}; run `make artifacts`");
        None
    }
}

fn executable_artifacts_dir() -> Option<String> {
    let dir = artifacts_dir()?;
    if XlaRuntime::backend_available() {
        Some(dir)
    } else {
        eprintln!("SKIP: no PJRT backend linked into this build");
        None
    }
}

/// A throwaway artifact dir with a manifest but no backing HLO files —
/// enough for registry and fallback tests.
fn temp_artifacts(tag: &str, manifest: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "bsf-xla-it-{tag}-{}-{:?}",
        std::process::id(),
        std::thread::current().id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.tsv"), manifest).unwrap();
    dir
}

#[test]
fn manifest_loads_and_lists_all_kinds() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = XlaRuntime::open(&dir).unwrap();
    for kind in ["jacobi", "jacobi_map", "cimmino", "gravity"] {
        assert!(
            rt.names().iter().any(|n| n.starts_with(kind)),
            "missing {kind} artifacts"
        );
    }
}

#[test]
fn best_chunk_picks_smallest_fitting() {
    let dir = temp_artifacts(
        "chunks",
        "jacobi_n64_c16\tjacobi\t64\t16\tf32[64]\ta.hlo.txt\n\
         jacobi_n64_c64\tjacobi\t64\t64\tf32[64]\tb.hlo.txt\n",
    );
    let rt = XlaRuntime::open(&dir).unwrap();
    assert_eq!(rt.best_chunk("jacobi", 64, 10).expect("fits in c=16").c, 16);
    assert_eq!(rt.best_chunk("jacobi", 64, 17).expect("fits in c=64").c, 64);
    assert!(rt.best_chunk("jacobi", 64, 65).is_none());
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn service_answers_registry_queries_across_threads() {
    let dir = temp_artifacts(
        "service",
        "jacobi_n64_c16\tjacobi\t64\t16\tf32[64]\ta.hlo.txt\n\
         gravity_n64_c16\tgravity\t64\t16\tf32[16,3]\tg.hlo.txt\n",
    );
    let service = XlaService::start(&dir).unwrap();
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let h = service.handle();
            std::thread::spawn(move || {
                let best = h.best_chunk("jacobi", 64, 5).unwrap();
                assert_eq!(best, Some(("jacobi_n64_c16".to_string(), 16)));
                assert_eq!(h.best_chunk("jacobi", 64, 999).unwrap(), None);
                assert_eq!(h.best_chunk("cimmino", 64, 5).unwrap(), None);
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn service_start_on_missing_dir_is_typed_error() {
    let err = XlaService::start("/definitely/not/an/artifact/dir").unwrap_err();
    assert!(matches!(err, BsfError::Io { .. }), "{err}");
}

#[test]
fn xla_backend_falls_back_to_native_when_nothing_fits() {
    // Manifest exists but holds no jacobi artifacts for n=40 → the
    // backend must warn once and produce *identical* results via the
    // native fallback (satisfying "recoverable artifact selection").
    let dir = temp_artifacts(
        "fallback",
        "jacobi_n64_c16\tjacobi\t64\t16\tf32[64]\ta.hlo.txt\n",
    );
    let service = XlaService::start(&dir).unwrap();
    let (p_xla, x_star) = JacobiProblem::random(40, 1e-18, 71);
    let (p_nat, _) = JacobiProblem::random(40, 1e-18, 71);
    let r_xla = Bsf::new(p_xla)
        .workers(3)
        .map_backend(XlaMapBackend::new(service.handle()))
        .run()
        .unwrap();
    let r_nat = Bsf::new(p_nat).workers(3).run().unwrap();
    assert_eq!(r_xla.iterations, r_nat.iterations);
    assert_eq!(r_xla.param, r_nat.param);
    assert!(dist2(&r_xla.param, &x_star) < 1e-10);
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn xla_backend_falls_back_when_backend_is_unavailable() {
    if XlaRuntime::backend_available() {
        return; // this test pins the no-backend degradation path
    }
    // The manifest *does* contain a fitting artifact, but there is no
    // PJRT binding: execution fails, the backend warns once and the run
    // still converges on the native map.
    let dir = temp_artifacts(
        "nobackend",
        "jacobi_n64_c16\tjacobi\t64\t16\tf32[64]\ta.hlo.txt\n\
         jacobi_n64_c64\tjacobi\t64\t64\tf32[64]\tb.hlo.txt\n",
    );
    std::fs::write(dir.join("a.hlo.txt"), "HloModule stub").unwrap();
    std::fs::write(dir.join("b.hlo.txt"), "HloModule stub").unwrap();
    let service = XlaService::start(&dir).unwrap();
    let (p, x_star) = JacobiProblem::random(64, 1e-18, 72);
    let r = Bsf::new(p)
        .workers(4)
        .map_backend(XlaMapBackend::new(service.handle()))
        .run()
        .unwrap();
    assert!(dist2(&r.param, &x_star) < 1e-10);
    let _ = std::fs::remove_dir_all(dir);
}

// ----------------------------------------------------------------------
// Execution tests: need real artifacts AND a linked PJRT backend.
// ----------------------------------------------------------------------

#[test]
fn jacobi_artifact_matches_native_matvec() {
    let Some(dir) = executable_artifacts_dir() else { return };
    let rt = XlaRuntime::open(&dir).unwrap();
    // jacobi_n64_c16: (64,16) @ (16,) -> (64,)
    let n = 64;
    let c = 16;
    let cols: Vec<f32> = (0..n * c).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect();
    let x: Vec<f32> = (0..c).map(|j| (j as f32 - 8.0) * 0.25).collect();
    let out = rt
        .execute_f32("jacobi_n64_c16", &[(&cols, &[n as i64, c as i64]), (&x, &[c as i64])])
        .unwrap();
    assert_eq!(out.len(), n);
    for i in 0..n {
        let want: f32 = (0..c).map(|j| cols[i * c + j] * x[j]).sum();
        assert!((out[i] - want).abs() < 1e-4, "row {i}: {} vs {want}", out[i]);
    }
}

#[test]
fn executable_cache_reuses_compilation() {
    let Some(dir) = executable_artifacts_dir() else { return };
    let rt = XlaRuntime::open(&dir).unwrap();
    let cols = vec![0.5f32; 64 * 16];
    let x = vec![1.0f32; 16];
    let t0 = std::time::Instant::now();
    let _ = rt
        .execute_f32("jacobi_n64_c16", &[(&cols, &[64, 16]), (&x, &[16])])
        .unwrap();
    let first = t0.elapsed();
    let t0 = std::time::Instant::now();
    for _ in 0..5 {
        let _ = rt
            .execute_f32("jacobi_n64_c16", &[(&cols, &[64, 16]), (&x, &[16])])
            .unwrap();
    }
    let warm = t0.elapsed() / 5;
    assert!(warm < first, "warm {warm:?} should beat cold {first:?}");
}

fn xla_session<P: bsf::runtime::backend::XlaMapSpec>(
    p: P,
    service: &XlaService,
    k: usize,
) -> Bsf<P> {
    Bsf::new(p).workers(k).map_backend(XlaMapBackend::new(service.handle()))
}

#[test]
fn xla_backed_jacobi_solves_like_native() {
    let Some(dir) = executable_artifacts_dir() else { return };
    let service = XlaService::start(&dir).unwrap();
    // n must be an AOT dimension (64) for the XLA path to engage.
    let (native, x_star) = JacobiProblem::random(64, 1e-10, 401);
    let (xla_p, _) = JacobiProblem::random(64, 1e-10, 401);
    let rn = Bsf::new(native).workers(4).run().unwrap();
    let rx = xla_session(xla_p, &service, 4).run().unwrap();
    // f32 kernel vs f64 native: same fixed point to f32 accuracy.
    assert!(dist2(&rx.param, &x_star) < 1e-4, "dist² {}", dist2(&rx.param, &x_star));
    assert!(dist2(&rn.param, &rx.param) < 1e-4);
}

#[test]
fn xla_backed_jacobi_map_solves() {
    let Some(dir) = executable_artifacts_dir() else { return };
    let service = XlaService::start(&dir).unwrap();
    let (p, x_star) = JacobiMapProblem::random(64, 1e-10, 402);
    let r = xla_session(p, &service, 4).run().unwrap();
    assert!(dist2(&r.param, &x_star) < 1e-4);
}

#[test]
fn xla_backed_cimmino_converges() {
    let Some(dir) = executable_artifacts_dir() else { return };
    let service = XlaService::start(&dir).unwrap();
    let (p, _) = CimminoProblem::random(64, 64, 1e-10, 403);
    let p = Arc::new(p);
    let r0 = p.residual2(&vec![0.0; 64]);
    let r = Bsf::from_arc(Arc::clone(&p))
        .workers(4)
        .max_iter(20_000)
        .map_backend(XlaMapBackend::new(service.handle()))
        .run()
        .unwrap();
    assert!(p.residual2(&r.param) < r0 * 1e-4);
}

#[test]
fn xla_backed_gravity_matches_native_trajectory() {
    let Some(dir) = executable_artifacts_dir() else { return };
    let service = XlaService::start(&dir).unwrap();
    let native = GravityProblem::random(64, 1e-3, 5, 404);
    let xla_p = GravityProblem::random(64, 1e-3, 5, 404);
    let rn = Bsf::new(native).workers(4).run().unwrap();
    let rx = xla_session(xla_p, &service, 4).run().unwrap();
    for (a, b) in rn.param.iter().zip(&rx.param) {
        assert!((a - b).abs() < 1e-3, "{a} vs {b}");
    }
}

#[test]
fn service_handles_work_from_many_threads() {
    let Some(dir) = executable_artifacts_dir() else { return };
    let service = XlaService::start(&dir).unwrap();
    let handles: Vec<_> = (0..8)
        .map(|t| {
            let h = service.handle();
            std::thread::spawn(move || {
                let cols = vec![t as f32; 64 * 16];
                let x = vec![1.0f32; 16];
                let out = h
                    .execute_f32(
                        "jacobi_n64_c16",
                        vec![(cols, vec![64, 16]), (x, vec![16])],
                    )
                    .unwrap();
                assert!((out[0] - 16.0 * t as f32).abs() < 1e-3);
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}
