//! E5 — the BSF model's headline table: scalability boundary **predicted
//! before implementation** (analytic K_max from calibration) vs the peak
//! observed on the simulated cluster, per application and size.

use bsf::bench::sweep::speedup_sweep;
use bsf::bench::Table;
use bsf::costmodel::ClusterProfile;
use bsf::problems::cimmino::CimminoProblem;
use bsf::problems::gravity::GravityProblem;
use bsf::problems::jacobi::JacobiProblem;
use bsf::problems::jacobi_map::JacobiMapProblem;
use bsf::problems::montecarlo::MonteCarloProblem;

fn main() {
    let profile = ClusterProfile::infiniband();
    // log-spaced K grid dense enough to locate peaks
    let ks: Vec<usize> = vec![
        1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48, 64, 96, 128, 192, 256, 384, 512,
    ];
    let mut table = Table::new(&[
        "app", "n", "K_max model", "K peak sim", "a(peak) model", "a(peak) sim", "ratio",
    ]);

    let mut add = |app: &str, n: usize, s: Result<bsf::bench::sweep::Sweep, bsf::BsfError>| {
        let s = s.expect("sweep");
        let peak_row = s.rows.iter().find(|r| r.k == s.k_peak_sim).unwrap();
        let ratio = if s.k_max_model.is_finite() && s.k_max_model > 0.0 {
            s.k_peak_sim as f64 / s.k_max_model
        } else {
            f64::NAN
        };
        table.row(&[
            app.to_string(),
            n.to_string(),
            format!("{:.1}", s.k_max_model),
            s.k_peak_sim.to_string(),
            format!("{:.2}", peak_row.a_model),
            format!("{:.2}", peak_row.a_sim),
            format!("{ratio:.2}"),
        ]);
    };

    for &n in &[512usize, 1024, 2048] {
        add(
            "jacobi",
            n,
            speedup_sweep(|| JacobiProblem::random(n, 1e-30, 7).0, &ks, profile, 5),
        );
    }
    for &n in &[512usize, 1024] {
        add(
            "jacobi-map",
            n,
            speedup_sweep(|| JacobiMapProblem::random(n, 1e-30, 7).0, &ks, profile, 5),
        );
        add(
            "cimmino",
            n,
            speedup_sweep(|| CimminoProblem::random(n, n, 1e-30, 7).0, &ks, profile, 5),
        );
        add(
            "gravity",
            n,
            speedup_sweep(|| GravityProblem::random(n, 1e-3, 3, 7), &ks, profile, 3),
        );
    }
    add(
        "montecarlo",
        4096,
        speedup_sweep(|| MonteCarloProblem::new(4096, 2_000, 1e-12), &ks, profile, 3),
    );

    println!("E5 — predicted vs observed scalability boundary (infiniband)");
    table.print();
    println!("\nratio = observed peak / analytic K_max (1.0 = perfect prediction;");
    println!("the model idealizes stragglers + master serialization, so ratios");
    println!("in [0.5, 2] reproduce the paper's 'prediction within a factor'.");
}
