//! E7 — skeleton overhead: the paper claims the skeleton "completely
//! encapsulates all aspects associated with parallelizing a program";
//! the implicit cost claim is that the encapsulation is cheap. Compare a
//! hand-rolled sequential Jacobi loop against the session API's three
//! engines at K=1: the serial fast path (no transport), the threaded
//! engine (full transport + codec + extended reduce + phase timers) and
//! the simulated cluster. Workload generation happens once, outside
//! every timed region.

use std::sync::Arc;

use bsf::bench::{bench, fmt_secs, Table};
use bsf::costmodel::ClusterProfile;
use bsf::problems::jacobi::JacobiProblem;
use bsf::skeleton::{Bsf, BsfConfig, SerialEngine, SimulatedEngine, ThreadedEngine};
use bsf::util::mat::{gen_diag_dominant, jacobi_cd, Mat};

/// Hand-rolled sequential Jacobi iterations (what a user would write
/// without the skeleton): same column-order accumulation as the fused
/// worker map, on prebuilt data.
fn handrolled(ct: &Mat, d: &[f64], iters: usize) -> Vec<f64> {
    let n = d.len();
    let mut x = d.to_vec();
    for _ in 0..iters {
        let mut s = vec![0.0f64; n];
        for j in 0..n {
            let xj = x[j];
            let cj = ct.row(j);
            for i in 0..n {
                s[i] += cj[i] * xj;
            }
        }
        for i in 0..n {
            x[i] = s[i] + d[i];
        }
    }
    x
}

fn main() {
    let n = 1024;
    let iters = 8;

    // Build the system once; all variants iterate on equivalent data.
    let (a, b, _) = gen_diag_dominant(n, 7);
    let (c, d) = jacobi_cd(&a, &b);
    let ct = c.transpose();
    let problem = Arc::new(JacobiProblem::from_system(&a, &b, 1e-30));
    let cfg = || BsfConfig::with_workers(1).max_iter(iters);

    let hr = bench("handrolled", 1, 5, || {
        std::hint::black_box(handrolled(&ct, &d, iters));
    });

    let serial = bench("serial K=1", 1, 5, || {
        let _ = Bsf::from_arc(Arc::clone(&problem))
            .config(cfg())
            .engine(SerialEngine)
            .run()
            .expect("serial run");
    });

    let threaded = bench("threaded K=1", 1, 5, || {
        let _ = Bsf::from_arc(Arc::clone(&problem))
            .config(cfg())
            .engine(ThreadedEngine)
            .run()
            .expect("threaded run");
    });

    let sim = bench("simcluster K=1", 1, 5, || {
        let _ = Bsf::from_arc(Arc::clone(&problem))
            .config(cfg())
            .engine(SimulatedEngine::new(ClusterProfile::infiniband()))
            .run()
            .expect("simulated run");
    });

    let per_iter = |r: &bsf::bench::BenchResult| r.median_secs / iters as f64;
    let hr_iter = per_iter(&hr);

    let mut t = Table::new(&["variant", "per-iter", "overhead vs handrolled"]);
    t.row(&["handrolled".into(), fmt_secs(hr_iter), "-".into()]);
    for (name, r) in [
        ("serial engine K=1", &serial),
        ("threaded engine K=1", &threaded),
        ("simcluster K=1 (real secs)", &sim),
    ] {
        t.row(&[
            name.into(),
            fmt_secs(per_iter(r)),
            format!("{:+.1}%", (per_iter(r) / hr_iter - 1.0) * 100.0),
        ]);
    }
    println!("E7 — skeleton overhead (jacobi n={n}, {iters} iters/run)");
    t.print();
    println!("\nthreaded overhead = transport + codec (one {n}-vector each way)");
    println!("+ extended-reduce bookkeeping per iteration; the serial engine");
    println!("shows the session API's K=1 fast path skipping all of it.");
}
