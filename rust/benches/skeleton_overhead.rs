//! E7 — skeleton overhead: the paper claims the skeleton "completely
//! encapsulates all aspects associated with parallelizing a program";
//! the implicit cost claim is that the encapsulation is cheap. Compare a
//! hand-rolled sequential Jacobi loop against the skeleton with K=1
//! (same arithmetic plus all skeleton machinery: transport, codec,
//! extended reduce, phase timers) and against the simulated cluster at
//! K=1. Workload generation happens once, outside every timed region.

use std::sync::Arc;

use bsf::bench::{bench, fmt_secs, Table};
use bsf::costmodel::ClusterProfile;
use bsf::problems::jacobi::JacobiProblem;
use bsf::simcluster::{run_simulated, SimConfig};
use bsf::skeleton::{run_threaded, BsfConfig};
use bsf::util::mat::{gen_diag_dominant, jacobi_cd, Mat};

/// Hand-rolled sequential Jacobi iterations (what a user would write
/// without the skeleton): same column-order accumulation as the fused
/// worker map, on prebuilt data.
fn handrolled(ct: &Mat, d: &[f64], iters: usize) -> Vec<f64> {
    let n = d.len();
    let mut x = d.to_vec();
    for _ in 0..iters {
        let mut s = vec![0.0f64; n];
        for j in 0..n {
            let xj = x[j];
            let cj = ct.row(j);
            for i in 0..n {
                s[i] += cj[i] * xj;
            }
        }
        for i in 0..n {
            x[i] = s[i] + d[i];
        }
    }
    x
}

fn main() {
    let n = 1024;
    let iters = 8;

    // Build the system once; all variants iterate on equivalent data.
    let (a, b, _) = gen_diag_dominant(n, 7);
    let (c, d) = jacobi_cd(&a, &b);
    let ct = c.transpose();
    let problem = Arc::new(JacobiProblem::from_system(&a, &b, 1e-30));

    let hr = bench("handrolled", 1, 5, || {
        std::hint::black_box(handrolled(&ct, &d, iters));
    });

    let sk = bench("skeleton K=1", 1, 5, || {
        let _ = run_threaded(
            Arc::clone(&problem),
            &BsfConfig::with_workers(1).max_iter(iters),
        );
    });

    let sim = bench("simcluster K=1", 1, 5, || {
        let _ = run_simulated(
            &*problem,
            &BsfConfig::with_workers(1).max_iter(iters),
            &SimConfig::new(ClusterProfile::infiniband()),
        );
    });

    let hr_iter = hr.median_secs / iters as f64;
    let sk_iter = sk.median_secs / iters as f64;
    let sim_iter = sim.median_secs / iters as f64;

    let mut t = Table::new(&["variant", "per-iter", "overhead vs handrolled"]);
    t.row(&["handrolled".into(), fmt_secs(hr_iter), "-".into()]);
    t.row(&[
        "skeleton K=1".into(),
        fmt_secs(sk_iter),
        format!("{:+.1}%", (sk_iter / hr_iter - 1.0) * 100.0),
    ]);
    t.row(&[
        "simcluster K=1 (real secs)".into(),
        fmt_secs(sim_iter),
        format!("{:+.1}%", (sim_iter / hr_iter - 1.0) * 100.0),
    ]);
    println!("E7 — skeleton overhead (jacobi n={n}, {iters} iters/run)");
    t.print();
    println!("\nskeleton overhead = transport + codec (one {n}-vector each way)");
    println!("+ extended-reduce bookkeeping per iteration, at K=1.");
}
