//! E3 — gravity (N-body) speedup curve: the compute-heavy extreme
//! (t_map = Θ(N²) per iteration with only Θ(N) communication), so the
//! scalability boundary sits far to the right of Jacobi's at equal N —
//! near-linear speedup through the sweep on InfiniBand.

use bsf::bench::sweep::{print_sweep, speedup_sweep};
use bsf::costmodel::ClusterProfile;
use bsf::problems::gravity::GravityProblem;

fn main() {
    let ks = [1usize, 2, 4, 8, 16, 32, 64, 128, 256, 512];
    for &n in &[512usize, 1024] {
        for (pname, profile) in [
            ("infiniband", ClusterProfile::infiniband()),
            ("gigabit", ClusterProfile::gigabit()),
        ] {
            let s = speedup_sweep(
                || GravityProblem::random(n, 1e-3, 3, 7),
                &ks,
                profile,
                3,
            )
            .expect("sweep");
            print_sweep(&format!("E3 gravity N={n}, {pname}"), &s);
        }
    }
}
