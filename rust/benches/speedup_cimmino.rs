//! E4 — Cimmino (row projections) speedup curve: same Θ(n²)/Θ(n)
//! structure as Jacobi but a different constant factor in t_map (two
//! dot products per row), placing its boundary near Jacobi's.

use bsf::bench::sweep::{print_sweep, speedup_sweep};
use bsf::costmodel::ClusterProfile;
use bsf::problems::cimmino::CimminoProblem;

fn main() {
    let ks = [1usize, 2, 4, 8, 16, 32, 64, 128, 256, 512];
    for &n in &[512usize, 1024] {
        let s = speedup_sweep(
            || CimminoProblem::random(n, n, 1e-30, 7).0,
            &ks,
            ClusterProfile::infiniband(),
            5,
        )
        .expect("sweep");
        print_sweep(&format!("E4 cimmino {n}x{n}, infiniband"), &s);
    }
}
