//! E6 — OpenMP support ablation (`PP_BSF_OMP` / `PP_BSF_NUM_THREADS`):
//! intra-worker thread count vs per-iteration wall time on the threaded
//! skeleton, for two map-function profiles:
//!
//! * **gravity** (compute-heavy map, tiny reduce element) — the case the
//!   paper's OpenMP mode is for: the parallel-for should scale;
//! * **jacobi per-element** (allocation-heavy map: every element builds
//!   an n-vector and ⊕ clones it) — the adversarial case, where extra
//!   threads mostly fight the allocator. The contrast is the point.

use std::sync::Arc;

use bsf::bench::{bench, fmt_secs, Table};
use bsf::problems::gravity::GravityProblem;
use bsf::problems::jacobi::JacobiProblem;
use bsf::skeleton::{Bsf, BsfConfig, PerElementBackend};

fn main() {
    let iters = 4;

    println!("E6 — OpenMP-analog ablation (K=2 workers)");

    // Compute-heavy map: gravity N=2048 (each element is O(N) flops).
    let grav = Arc::new(GravityProblem::random(2048, 1e-3, iters, 7));
    let mut t = Table::new(&["omp threads", "wall/iter", "speedup vs 1"]);
    let mut base = None;
    for threads in [1usize, 2, 4, 8] {
        let r = bench(format!("grav omp{threads}"), 1, 3, || {
            let cfg = BsfConfig::with_workers(2).threads_per_worker(threads).max_iter(iters);
            let _ = Bsf::from_arc(Arc::clone(&grav))
                .config(cfg)
                .map_backend(PerElementBackend)
                .run()
                .expect("gravity run");
        });
        let per_iter = r.median_secs / iters as f64;
        let b = *base.get_or_insert(per_iter);
        t.row(&[
            threads.to_string(),
            fmt_secs(per_iter),
            format!("{:.2}", b / per_iter),
        ]);
    }
    println!("\ngravity N=2048 (compute-heavy map — OpenMP's target case)");
    t.print();

    // Allocation-heavy map: jacobi per-element (adversarial case).
    let jac = Arc::new(JacobiProblem::random(1536, 1e-30, 7).0);
    let mut t = Table::new(&["omp threads", "wall/iter", "speedup vs 1"]);
    let mut base = None;
    for threads in [1usize, 2, 4, 8] {
        let r = bench(format!("jac omp{threads}"), 1, 3, || {
            let cfg = BsfConfig::with_workers(2).threads_per_worker(threads).max_iter(iters);
            let _ = Bsf::from_arc(Arc::clone(&jac))
                .config(cfg)
                .map_backend(PerElementBackend)
                .run()
                .expect("jacobi run");
        });
        let per_iter = r.median_secs / iters as f64;
        let b = *base.get_or_insert(per_iter);
        t.row(&[
            threads.to_string(),
            fmt_secs(per_iter),
            format!("{:.2}", b / per_iter),
        ]);
    }
    println!("\njacobi n=1536 per-element (allocation-bound map — threads can't help)");
    t.print();

    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    println!("\nhost cores: {cores}. On a 1-core testbed both tables are flat by");
    println!("construction — the ablation demonstrates correctness (identical");
    println!("results at every thread count, asserted in the test suite) and");
    println!("scales with physical cores on larger hosts.");
}
