//! E2 — Jacobi **Map-without-Reduce** (Algorithm 4) speedup curve, to
//! compare against E1: the per-worker result message shrinks from a full
//! n-vector to the worker's coordinate block, shifting the boundary.

use bsf::bench::sweep::{print_sweep, speedup_sweep};
use bsf::costmodel::ClusterProfile;
use bsf::problems::jacobi_map::JacobiMapProblem;

fn main() {
    let ks = [1usize, 2, 4, 8, 16, 32, 64, 128, 256, 512];
    for &n in &[512usize, 1024, 2048] {
        let s = speedup_sweep(
            || JacobiMapProblem::random(n, 1e-30, 7).0,
            &ks,
            ClusterProfile::infiniband(),
            5,
        )
        .expect("sweep");
        print_sweep(&format!("E2 jacobi-map n={n}, infiniband"), &s);
    }
}
