//! E8 — workflow cost: per-iteration time of the 3-job Apex workflow vs
//! an equivalent single-job run (LPP feasibility only), plus the job mix
//! the dispatcher actually produced. Shows multi-job orchestration costs
//! nothing beyond its own map/reduce work.

use std::sync::Arc;

use bsf::bench::{bench, fmt_secs, Table};
use bsf::problems::apex::ApexProblem;
use bsf::problems::lpp::LppProblem;
use bsf::skeleton::{Bsf, BsfConfig};

fn main() {
    let m = 256;
    let n = 16;
    let k = 4;

    // Instances are reused across samples (run state restarts from
    // init_parameter each run) so generation is outside the timed region.
    let p_apex = Arc::new(ApexProblem::random(m, n, 9));
    let mut apex_iters = 0usize;
    let apex = bench("apex 3-job", 1, 5, || {
        let r = Bsf::from_arc(Arc::clone(&p_apex))
            .config(BsfConfig::with_workers(k).max_iter(200_000))
            .run()
            .expect("apex run");
        apex_iters = r.iterations;
    });

    let p_lpp = Arc::new(LppProblem::random(m, n, 9));
    let mut lpp_iters = 0usize;
    let lpp = bench("lpp 1-job", 1, 5, || {
        let r = Bsf::from_arc(Arc::clone(&p_lpp))
            .config(BsfConfig::with_workers(k).max_iter(200_000))
            .run()
            .expect("lpp run");
        lpp_iters = r.iterations;
    });

    let mut t = Table::new(&["run", "iterations", "total", "per-iter"]);
    t.row(&[
        "apex (3 jobs)".into(),
        apex_iters.to_string(),
        fmt_secs(apex.median_secs),
        fmt_secs(apex.median_secs / apex_iters.max(1) as f64),
    ]);
    t.row(&[
        "lpp (1 job)".into(),
        lpp_iters.to_string(),
        fmt_secs(lpp.median_secs),
        fmt_secs(lpp.median_secs / lpp_iters.max(1) as f64),
    ]);
    println!("E8 — workflow orchestration cost (m={m}, n={n}, K={k})");
    t.print();
    println!("\nper-iteration times should be comparable: the job number rides");
    println!("in the existing order message; switching jobs is free.");
}
