//! E1 — Jacobi speedup curve a(K): BSF-model prediction vs simulated
//! cluster, for several problem sizes and both interconnect profiles.
//! Regenerates the companion-paper's Jacobi scalability figure (curve
//! shape + boundary position; absolute times are this machine's).

use bsf::bench::sweep::{print_sweep, speedup_sweep};
use bsf::costmodel::ClusterProfile;
use bsf::problems::jacobi::JacobiProblem;

fn main() {
    let ks = [1usize, 2, 4, 8, 16, 32, 64, 128, 256, 512];
    for &n in &[512usize, 1024, 2048] {
        for (pname, profile) in [
            ("infiniband", ClusterProfile::infiniband()),
            ("gigabit", ClusterProfile::gigabit()),
        ] {
            let s = speedup_sweep(
                || JacobiProblem::random(n, 1e-30, 7).0,
                &ks,
                profile,
                5,
            )
            .expect("sweep");
            print_sweep(&format!("E1 jacobi n={n}, {pname}"), &s);
        }
    }
}
