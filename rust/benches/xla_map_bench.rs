//! E9 — L1/L2 integration bench: per-iteration worker map cost, native
//! fused Rust loop vs the AOT Pallas/XLA kernel through the PJRT service
//! (n=1024, chunk=256 — the largest compiled variant). Also measures the
//! service round-trip overhead with a tiny kernel.
//!
//! Requires `make artifacts` and a linked PJRT backend; exits 0 with a
//! note when either is absent.

use std::sync::Arc;

use bsf::bench::{bench, fmt_secs, Table};
use bsf::problems::jacobi::JacobiProblem;
use bsf::runtime::backend::XlaMapBackend;
use bsf::runtime::service::XlaService;
use bsf::runtime::XlaRuntime;
use bsf::skeleton::{Bsf, BsfConfig};

fn main() {
    if !XlaRuntime::backend_available() {
        println!("E9 skipped: no PJRT backend linked into this build");
        return;
    }
    let service = match XlaService::start_default() {
        Ok(s) => s,
        Err(e) => {
            println!("E9 skipped: {e} (run `make artifacts`)");
            return;
        }
    };

    let n = 1024;
    let iters = 6;
    let k = 4;

    // Problems are built once and reused (Arc) so the timed region is
    // the skeleton iterations, not workload generation.
    let p_native = Arc::new(JacobiProblem::random(n, 1e-30, 11).0);
    let native = bench("native", 1, 5, || {
        let _ = Bsf::from_arc(Arc::clone(&p_native))
            .config(BsfConfig::with_workers(k).max_iter(iters))
            .run()
            .expect("native run");
    });

    let p_xla = Arc::new(JacobiProblem::random(n, 1e-30, 11).0);
    // One shared backend keeps the chunk/static-input caches warm across
    // samples (the §Perf point this bench measures).
    let backend: Arc<dyn bsf::skeleton::MapBackend<JacobiProblem>> =
        Arc::new(XlaMapBackend::new(service.handle()));
    let xla = bench("xla", 1, 5, || {
        let _ = Bsf::from_arc(Arc::clone(&p_xla))
            .config(BsfConfig::with_workers(k).max_iter(iters))
            .map_backend_arc(Arc::clone(&backend))
            .run()
            .expect("xla run");
    });

    // Service round-trip floor: smallest artifact, warm cache.
    let h2 = service.handle();
    let cols = vec![0.5f32; 64 * 16];
    let x = vec![1.0f32; 16];
    let rt = bench("roundtrip", 3, 50, || {
        let _ = h2
            .execute_f32(
                "jacobi_n64_c16",
                vec![(cols.clone(), vec![64, 16]), (x.clone(), vec![16])],
            )
            .unwrap();
    });

    let mut t = Table::new(&["worker map backend", "per-iteration (K=4)"]);
    t.row(&["native fused Rust".into(), fmt_secs(native.median_secs / iters as f64)]);
    t.row(&["AOT Pallas/XLA via PJRT".into(), fmt_secs(xla.median_secs / iters as f64)]);
    println!("E9 — worker map backends (jacobi n={n})");
    t.print();
    println!(
        "\nPJRT service round-trip floor (64x16 kernel, warm): {}",
        fmt_secs(rt.median_secs)
    );
    println!("±MAD {}", fmt_secs(rt.mad_secs));
}
