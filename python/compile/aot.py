"""AOT pipeline: lower the L2 chunk map functions to HLO *text* artifacts.

HLO text (NOT ``lowered.compile().serialize()`` / serialized
HloModuleProto) is the interchange format: jax >= 0.5 emits protos with
64-bit instruction ids which the ``xla`` crate's xla_extension 0.5.1
rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/README.md.

Outputs:
  artifacts/<name>.hlo.txt   one module per SPECS entry
  artifacts/manifest.tsv     name \t kind \t n \t c \t out-shape \t file

Run once via ``make artifacts``; the Rust binary is self-contained after.
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def emit_all(out_dir: str) -> list[tuple[str, dict]]:
    os.makedirs(out_dir, exist_ok=True)
    rows = []
    for name, fn, args, meta in model.specs():
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        rows.append((name, meta))
        print(f"  {name}: {len(text)} chars -> {path}")
    manifest = os.path.join(out_dir, "manifest.tsv")
    with open(manifest, "w") as f:
        for name, meta in rows:
            f.write(
                f"{name}\t{meta['kind']}\t{meta['n']}\t{meta['c']}"
                f"\t{meta['out']}\t{name}.hlo.txt\n"
            )
    print(f"wrote {len(rows)} artifacts + {manifest}")
    return rows


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts",
                   help="artifact output directory")
    args = p.parse_args()
    emit_all(args.out)


if __name__ == "__main__":
    main()
