"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Each function here is the *mathematical definition* of the corresponding
chunk-level map function used by the BSF workers:

* ``jacobi_chunk``      — fused Map+local-Reduce of Algorithm 3: a worker
  holding columns ``c_j`` of the iteration matrix C and the matching
  coordinates ``x_j`` of the current approximation computes the partial sum
  ``sum_j x_j * c_j`` (a column-scaled accumulation == C_chunk @ x_chunk).
* ``jacobi_map_chunk``  — Map-without-Reduce of Algorithm 4: a worker
  holding rows of C computes its coordinates of the next approximation
  ``C_rows @ x + d_chunk``.
* ``cimmino_chunk``     — fused Map+local-Reduce for the Cimmino row
  projection method: correction ``A_chunk^T @ ((b - A_chunk x) * w)`` with
  per-row weights ``w_i = lambda / ||a_i||^2``.
* ``gravity_chunk``     — per-body acceleration for an N-body chunk with
  Plummer softening.

The Pallas kernels in this package must match these to ~1e-5 (f32).
"""

import jax.numpy as jnp


def jacobi_chunk(c_cols, x_chunk):
    """Partial fold of Algorithm 3 on one worker.

    Args:
      c_cols:  (n, c) — the worker's ``c`` columns of the n x n matrix C.
      x_chunk: (c,)   — the matching coordinates of the approximation.

    Returns:
      (n,) partial sum  ``sum_j x_chunk[j] * c_cols[:, j]``.
    """
    return c_cols @ x_chunk


def jacobi_map_chunk(c_rows, x, d_chunk):
    """Map-only Jacobi step (Algorithm 4) on one worker.

    Args:
      c_rows:  (c, n) — the worker's rows of C.
      x:       (n,)   — full current approximation.
      d_chunk: (c,)   — matching entries of d.

    Returns:
      (c,) — the worker's coordinates of the next approximation.
    """
    return c_rows @ x + d_chunk


def cimmino_chunk(a_rows, b_chunk, x, w_chunk):
    """Fused Cimmino projection correction for one worker's rows.

    Args:
      a_rows:  (c, n) — the worker's rows of A.
      b_chunk: (c,)   — matching right-hand sides.
      x:       (n,)   — full current approximation.
      w_chunk: (c,)   — per-row weights (relaxation / ||a_i||^2).

    Returns:
      (n,) partial correction  ``sum_i w_i (b_i - a_i.x) a_i``.
    """
    r = (b_chunk - a_rows @ x) * w_chunk
    return a_rows.T @ r


def gravity_chunk(p_chunk, p_all, m_all, eps=1e-2, g=1.0):
    """Accelerations of a chunk of bodies under softened Newtonian gravity.

    Args:
      p_chunk: (c, 3) — positions of the worker's bodies.
      p_all:   (n, 3) — positions of all bodies.
      m_all:   (n,)   — masses of all bodies.
      eps:     Plummer softening (the i==i pair has diff 0 so
               contributes nothing).
      g:       gravitational constant.

    Returns:
      (c, 3) accelerations.
    """
    diff = p_all[None, :, :] - p_chunk[:, None, :]          # (c, n, 3)
    r2 = jnp.sum(diff * diff, axis=-1) + eps * eps          # (c, n)
    inv_r3 = jnp.power(r2, -1.5)                            # (c, n)
    w = m_all[None, :] * inv_r3                             # (c, n)
    return g * jnp.sum(w[:, :, None] * diff, axis=1)        # (c, 3)
