"""L1: Pallas kernels for the BSF worker map hot-spots.

One module per demo application (jacobi, cimmino, gravity) plus the
pure-jnp oracle in :mod:`ref`.  All kernels run under ``interpret=True``
(CPU image; see the module docstrings for the TPU mapping notes).
"""

from . import cimmino, gravity, jacobi, ref  # noqa: F401
