"""Pallas kernel for the Cimmino worker map function (L1).

The Cimmino method is the row-projection iterative solver the BSF papers
use as a second linear-algebra demo: each map element is one row ``a_i`` of
A, its image is the scaled projection correction ``w_i (b_i - a_i.x) a_i``,
and Reduce is vector addition.  A worker's fused Map+local-Reduce over its
row block is therefore

    out = A_chunk^T @ ((b_chunk - A_chunk @ x) * w_chunk)      # (n,)

The kernel tiles the worker's rows; each grid step computes the residual of
one row tile and accumulates its correction into the single (n,) output
block (the output BlockSpec maps every grid step to block 0, a sequential-
grid accumulation — the standard TPU reduction idiom).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(n: int, pref: int) -> int:
    if n <= pref:
        return n
    for b in range(pref, 0, -1):
        if n % b == 0:
            return b
    return n


def cimmino_chunk(a_rows, b_chunk, x, w_chunk, block_c: int = 64):
    """Fused Cimmino correction ``A^T ((b - A x) * w)`` over a row block.

    Args:
      a_rows:  (c, n) f32 — the worker's rows of A.
      b_chunk: (c,)   f32 — matching right-hand sides.
      x:       (n,)   f32 — full current approximation.
      w_chunk: (c,)   f32 — per-row weights (relaxation / ||a_i||^2).
      block_c: preferred row tile height.

    Returns:
      (n,) f32 partial correction.
    """
    c, n = a_rows.shape
    bc = _pick_block(c, block_c)

    def kernel(a_ref, b_ref, x_ref, w_ref, o_ref):
        i = pl.program_id(0)

        @pl.when(i == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        r = (b_ref[...] - a_ref[...] @ x_ref[...]) * w_ref[...]   # (bc,)
        o_ref[...] += r @ a_ref[...]                              # (n,)

    return pl.pallas_call(
        kernel,
        grid=(c // bc,),
        in_specs=[
            pl.BlockSpec((bc, n), lambda i: (i, 0)),
            pl.BlockSpec((bc,), lambda i: (i,)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((bc,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((n,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n,), a_rows.dtype),
        interpret=True,
    )(a_rows, b_chunk, x, w_chunk)
