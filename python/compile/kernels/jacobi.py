"""Pallas kernels for the Jacobi worker map functions (L1).

Two kernels, matching the two BSF formulations in the paper:

* ``jacobi_chunk``     — Algorithm 3 (Map + Reduce): the worker's fused
  Map+local-fold over its column sublist, ``sum_j x_j * c_j``.  Tiled over
  the output dimension n so each grid step holds a ``(block_n, c)`` tile of
  the column block in VMEM and emits a ``(block_n,)`` slice of the partial
  sum.
* ``jacobi_map_chunk`` — Algorithm 4 (Map without Reduce): the worker's
  rows of the next approximation, ``C_rows @ x + d``.  Tiled over the
  worker's row count c.

Both are lowered with ``interpret=True`` — on this CPU image a real TPU
lowering would emit a Mosaic custom-call the CPU PJRT plugin cannot run.
TPU notes (see DESIGN.md §Hardware-Adaptation): the matvec tiles are laid
out so the MXU sees a ``(block, c) x (c, 1)`` contraction; ``block_n`` is
chosen to keep the C tile + x + out slice comfortably inside ~16 MiB VMEM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(n: int, pref: int) -> int:
    """Largest divisor of n that is <= pref (falls back to n)."""
    if n <= pref:
        return n
    for b in range(pref, 0, -1):
        if n % b == 0:
            return b
    return n


def jacobi_chunk(c_cols, x_chunk, block_n: int = 128):
    """Fused Map+local-Reduce of Algorithm 3: ``c_cols @ x_chunk``.

    Args:
      c_cols:  (n, c) f32 — the worker's columns of C.
      x_chunk: (c,)   f32 — matching coordinates of the approximation.
      block_n: preferred output tile height.

    Returns:
      (n,) f32 partial sum.
    """
    n, c = c_cols.shape
    bn = _pick_block(n, block_n)

    def kernel(c_ref, x_ref, o_ref):
        o_ref[...] = c_ref[...] @ x_ref[...]

    return pl.pallas_call(
        kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, c), lambda i: (i, 0)),
            pl.BlockSpec((c,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), c_cols.dtype),
        interpret=True,
    )(c_cols, x_chunk)


def jacobi_map_chunk(c_rows, x, d_chunk, block_c: int = 128):
    """Map-only Jacobi step of Algorithm 4: ``c_rows @ x + d_chunk``.

    Args:
      c_rows:  (c, n) f32 — the worker's rows of C.
      x:       (n,)   f32 — full current approximation.
      d_chunk: (c,)   f32 — matching entries of d.
      block_c: preferred row tile height.

    Returns:
      (c,) f32 — the worker's coordinates of the next approximation.
    """
    c, n = c_rows.shape
    bc = _pick_block(c, block_c)

    def kernel(c_ref, x_ref, d_ref, o_ref):
        o_ref[...] = c_ref[...] @ x_ref[...] + d_ref[...]

    return pl.pallas_call(
        kernel,
        grid=(c // bc,),
        in_specs=[
            pl.BlockSpec((bc, n), lambda i: (i, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((bc,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bc,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((c,), c_rows.dtype),
        interpret=True,
    )(c_rows, x, d_chunk)
