"""Pallas kernel for the gravity (N-body) worker map function (L1).

This is the BSF-gravity demo application: the map-list is the list of
bodies; a worker computes the acceleration of each of its bodies against
*all* bodies (an O(c*N) tile of the O(N^2) interaction matrix).  Reduce is
not needed (Map-without-Reduce shape, like Algorithm 4) — each worker owns
its output slice.

The kernel keeps the worker's chunk positions (c, 3) resident and streams
source-body tiles (block_j, 3) through VMEM, accumulating into the (c, 3)
output block across the sequential grid — the classic N-body "j-loop
blocking" mapped to a Pallas grid.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(n: int, pref: int) -> int:
    if n <= pref:
        return n
    for b in range(pref, 0, -1):
        if n % b == 0:
            return b
    return n


def gravity_chunk(p_chunk, p_all, m_all, eps: float = 1e-2, g: float = 1.0,
                  block_j: int = 256):
    """Softened pairwise accelerations of a chunk of bodies.

    Args:
      p_chunk: (c, 3) f32 — positions of the worker's bodies.
      p_all:   (n, 3) f32 — positions of all bodies.
      m_all:   (n,)   f32 — masses of all bodies.
      eps:     Plummer softening (static; the self-pair contributes 0).
      g:       gravitational constant (static).
      block_j: preferred source-body tile.

    Returns:
      (c, 3) f32 accelerations.
    """
    c = p_chunk.shape[0]
    n = p_all.shape[0]
    bj = _pick_block(n, block_j)
    eps2 = float(eps) * float(eps)
    gc = float(g)

    def kernel(pi_ref, p_ref, m_ref, o_ref):
        j = pl.program_id(0)

        @pl.when(j == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        pi = pi_ref[...]                                     # (c, 3)
        pj = p_ref[...]                                      # (bj, 3)
        diff = pj[None, :, :] - pi[:, None, :]               # (c, bj, 3)
        r2 = jnp.sum(diff * diff, axis=-1) + eps2            # (c, bj)
        w = m_ref[...][None, :] * jax.lax.rsqrt(r2) / r2     # m / r^3
        o_ref[...] += gc * jnp.sum(w[:, :, None] * diff, axis=1)

    return pl.pallas_call(
        kernel,
        grid=(n // bj,),
        in_specs=[
            pl.BlockSpec((c, 3), lambda j: (0, 0)),
            pl.BlockSpec((bj, 3), lambda j: (j, 0)),
            pl.BlockSpec((bj,), lambda j: (j,)),
        ],
        out_specs=pl.BlockSpec((c, 3), lambda j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, 3), p_chunk.dtype),
        interpret=True,
    )(p_chunk, p_all, m_all)
