"""L2: chunk-level JAX map functions for the BSF workers.

Each entry point here is the computation *one BSF worker* performs per
iteration on its map-sublist (the paper's ``PC_bsf_MapF`` applied to the
whole sublist, fused with the local Reduce where the algorithm has one).
They call the Pallas kernels from :mod:`compile.kernels` so that the
kernel lowers into the same HLO module, and are AOT-lowered once by
:mod:`compile.aot` into ``artifacts/*.hlo.txt`` for the Rust runtime.

Shapes are static (XLA AOT requirement).  ``SPECS`` enumerates the
artifact variants the Rust side may load; the runtime pads a worker's
actual sublist up to the nearest compiled chunk size (padding is exact:
zero columns / zero-weight rows / zero-mass bodies contribute nothing).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import cimmino as k_cimmino
from .kernels import gravity as k_gravity
from .kernels import jacobi as k_jacobi

F32 = jnp.float32


# --------------------------------------------------------------------------
# Chunk map functions (the functions that get AOT-compiled).
# Every function returns a 1-tuple: the rust loader unwraps with to_tuple1.
# --------------------------------------------------------------------------

# Block-shape note (§Perf, L1): the AOT variants use a SINGLE grid step
# (block == full chunk). The worker chunks are small enough that the
# whole tile fits a TPU core's VMEM budget (largest: jacobi n=1024,
# c=256 -> 1 MiB C-block + 1 KiB x + 4 KiB out), and on the CPU
# interpret/PJRT path a 1-step grid lowers to one fused contraction
# instead of a while-loop of dynamic-update-slices (measured 5-10x
# faster; see EXPERIMENTS.md §Perf). The tiled multi-step path is still
# exercised by the pytest suite with explicit small block sizes.

def jacobi_chunk(c_cols, x_chunk):
    """Algorithm 3 worker step: partial sum over a column sublist."""
    return (k_jacobi.jacobi_chunk(c_cols, x_chunk, block_n=c_cols.shape[0]),)


def jacobi_map_chunk(c_rows, x, d_chunk):
    """Algorithm 4 worker step: the worker's slice of the next x."""
    return (k_jacobi.jacobi_map_chunk(c_rows, x, d_chunk, block_c=c_rows.shape[0]),)


def cimmino_chunk(a_rows, b_chunk, x, w_chunk):
    """Cimmino worker step: partial projection correction."""
    return (k_cimmino.cimmino_chunk(a_rows, b_chunk, x, w_chunk,
                                    block_c=a_rows.shape[0]),)


def gravity_chunk(p_chunk, p_all, m_all):
    """Gravity worker step: accelerations of the worker's bodies."""
    return (k_gravity.gravity_chunk(p_chunk, p_all, m_all,
                                    block_j=p_all.shape[0]),)


# --------------------------------------------------------------------------
# AOT specs: (artifact name, function, example-arg shapes)
# --------------------------------------------------------------------------

def _s(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def specs(n_list=(64, 1024), chunk_list=(16, 64, 256)):
    """Enumerate artifact variants.

    For each problem size n we emit chunk sizes <= n.  n=64/chunk=16 is the
    fast-test variant; n=1024 are the experiment variants (E1-E4).
    """
    out = []
    for n in n_list:
        for c in chunk_list:
            if c > n:
                continue
            out.append((
                f"jacobi_n{n}_c{c}", jacobi_chunk, (_s(n, c), _s(c)),
                {"kind": "jacobi", "n": n, "c": c, "out": f"f32[{n}]"},
            ))
            out.append((
                f"jacobi_map_n{n}_c{c}", jacobi_map_chunk,
                (_s(c, n), _s(n), _s(c)),
                {"kind": "jacobi_map", "n": n, "c": c, "out": f"f32[{c}]"},
            ))
            out.append((
                f"cimmino_n{n}_c{c}", cimmino_chunk,
                (_s(c, n), _s(c), _s(n), _s(c)),
                {"kind": "cimmino", "n": n, "c": c, "out": f"f32[{n}]"},
            ))
            out.append((
                f"gravity_n{n}_c{c}", gravity_chunk,
                (_s(c, 3), _s(n, 3), _s(n)),
                {"kind": "gravity", "n": n, "c": c, "out": f"f32[{c},3]"},
            ))
    return out
