"""L2/AOT tests: model chunk functions, spec enumeration, HLO text emission."""

import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import aot, model
from compile.kernels import ref

RNG = np.random.default_rng(1)


def _arr(*shape):
    return jnp.asarray(RNG.standard_normal(shape).astype(np.float32))


def test_model_functions_return_1_tuples():
    out = model.jacobi_chunk(_arr(8, 4), _arr(4))
    assert isinstance(out, tuple) and len(out) == 1
    out = model.gravity_chunk(_arr(2, 3), _arr(4, 3), jnp.abs(_arr(4)))
    assert isinstance(out, tuple) and len(out) == 1


def test_model_matches_ref():
    c_cols, x = _arr(16, 8), _arr(8)
    np.testing.assert_allclose(
        model.jacobi_chunk(c_cols, x)[0], ref.jacobi_chunk(c_cols, x),
        rtol=1e-5, atol=1e-5)
    a, b, xx, w = _arr(8, 16), _arr(8), _arr(16), _arr(8)
    np.testing.assert_allclose(
        model.cimmino_chunk(a, b, xx, w)[0],
        ref.cimmino_chunk(a, b, xx, w), rtol=1e-4, atol=1e-4)


def test_specs_enumeration():
    s = model.specs(n_list=(64,), chunk_list=(16, 64, 256))
    names = [row[0] for row in s]
    # chunk 256 > n 64 must be skipped; 2 chunk sizes x 4 kinds = 8
    assert len(s) == 8
    assert "jacobi_n64_c16" in names and "gravity_n64_c64" in names
    assert not any("c256" in n for n in names)


def test_specs_shapes_consistent():
    for name, fn, args, meta in model.specs(n_list=(64,), chunk_list=(16,)):
        concrete = [jnp.zeros(a.shape, a.dtype) for a in args]
        (out,) = fn(*concrete)
        assert f"f32[{','.join(str(d) for d in out.shape)}]" == meta["out"]


def test_hlo_text_emission(tmp_path):
    lowered = jax.jit(model.jacobi_chunk).lower(
        jax.ShapeDtypeStruct((8, 4), jnp.float32),
        jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text and "f32[8,4]" in text
    # text must be parseable-looking HLO, not a serialized proto
    assert text.lstrip().startswith("HloModule")


def test_emit_all_writes_manifest(tmp_path, monkeypatch):
    # shrink the spec set for speed
    small = model.specs(n_list=(16,), chunk_list=(4,))
    monkeypatch.setattr(model, "specs", lambda **kw: small)
    rows = aot.emit_all(str(tmp_path))
    manifest = os.path.join(str(tmp_path), "manifest.tsv")
    assert os.path.exists(manifest)
    lines = open(manifest).read().strip().splitlines()
    assert len(lines) == len(rows)
    for line in lines:
        name, kind, n, c, out, fname = line.split("\t")
        path = os.path.join(str(tmp_path), fname)
        assert os.path.exists(path)
        head = open(path).read(200)
        assert head.startswith("HloModule")
