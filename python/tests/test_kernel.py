"""L1 correctness: every Pallas kernel vs the pure-jnp oracle (ref.py).

This is the CORE correctness signal for the compute layer: hypothesis
sweeps shapes (and block sizes, so both the single-block and the tiled /
accumulating grid paths are exercised) and asserts allclose against ref.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import cimmino as k_cimmino
from compile.kernels import gravity as k_gravity
from compile.kernels import jacobi as k_jacobi
from compile.kernels import ref

RNG = np.random.default_rng(0)


def _arr(*shape, scale=1.0):
    return jnp.asarray(
        RNG.standard_normal(shape).astype(np.float32) * scale)


dims = st.integers(min_value=1, max_value=96)
blocks = st.sampled_from([1, 3, 8, 32, 128])


# ---------------------------------------------------------------- jacobi

@settings(max_examples=25, deadline=None)
@given(n=dims, c=dims, block=blocks)
def test_jacobi_chunk_matches_ref(n, c, block):
    c_cols, x = _arr(n, c), _arr(c)
    got = k_jacobi.jacobi_chunk(c_cols, x, block_n=block)
    want = ref.jacobi_chunk(c_cols, x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(n=dims, c=dims, block=blocks)
def test_jacobi_map_chunk_matches_ref(n, c, block):
    c_rows, x, d = _arr(c, n), _arr(n), _arr(c)
    got = k_jacobi.jacobi_map_chunk(c_rows, x, d, block_c=block)
    want = ref.jacobi_map_chunk(c_rows, x, d)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_jacobi_chunk_zero_x_gives_zero():
    c_cols = _arr(16, 8)
    out = k_jacobi.jacobi_chunk(c_cols, jnp.zeros(8, jnp.float32))
    np.testing.assert_array_equal(np.asarray(out), np.zeros(16))


def test_jacobi_chunk_identity_columns():
    # C = I(8) as one chunk: partial sum must equal x itself.
    x = _arr(8)
    out = k_jacobi.jacobi_chunk(jnp.eye(8, dtype=jnp.float32), x)
    np.testing.assert_allclose(out, x, rtol=1e-6)


def test_jacobi_chunk_additivity_over_sublists():
    # The defining BSF property: folding partial sums over split sublists
    # equals the unsplit fold (Reduce associativity at kernel level).
    n, c = 32, 24
    c_cols, x = _arr(n, c), _arr(c)
    full = k_jacobi.jacobi_chunk(c_cols, x)
    left = k_jacobi.jacobi_chunk(c_cols[:, :10], x[:10])
    right = k_jacobi.jacobi_chunk(c_cols[:, 10:], x[10:])
    np.testing.assert_allclose(left + right, full, rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------- cimmino

@settings(max_examples=25, deadline=None)
@given(n=dims, c=dims, block=blocks)
def test_cimmino_chunk_matches_ref(n, c, block):
    a, b, x, w = _arr(c, n), _arr(c), _arr(n), _arr(c, scale=0.1)
    got = k_cimmino.cimmino_chunk(a, b, x, w, block_c=block)
    want = ref.cimmino_chunk(a, b, x, w)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_cimmino_zero_weights_give_zero():
    a, b, x = _arr(6, 12), _arr(6), _arr(12)
    out = k_cimmino.cimmino_chunk(a, b, x, jnp.zeros(6, jnp.float32))
    np.testing.assert_array_equal(np.asarray(out), np.zeros(12))


def test_cimmino_exact_solution_fixed_point():
    # If x solves A x = b the correction is exactly zero.
    n = 8
    a = jnp.eye(n, dtype=jnp.float32) * 2.0
    x = _arr(n)
    b = a @ x
    w = 1.0 / jnp.sum(a * a, axis=1)
    out = k_cimmino.cimmino_chunk(a, b, x, w)
    np.testing.assert_allclose(out, np.zeros(n), atol=1e-5)


# --------------------------------------------------------------- gravity

@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 64), c=st.integers(1, 32),
       block=st.sampled_from([1, 4, 16, 64]))
def test_gravity_chunk_matches_ref(n, c, block):
    c = min(c, n)
    p_all = _arr(n, 3)
    m = jnp.abs(_arr(n)) + 0.1
    p_chunk = p_all[:c]
    got = k_gravity.gravity_chunk(p_chunk, p_all, m, block_j=block)
    want = ref.gravity_chunk(p_chunk, p_all, m)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_gravity_two_body_symmetry():
    # Equal masses on the x axis: forces are equal and opposite.
    p = jnp.asarray([[-1.0, 0, 0], [1.0, 0, 0]], jnp.float32)
    m = jnp.ones(2, jnp.float32)
    acc = k_gravity.gravity_chunk(p, p, m)
    np.testing.assert_allclose(acc[0], -acc[1], rtol=1e-6)
    assert acc[0, 0] > 0  # attraction toward the other body


def test_gravity_massless_sources_no_force():
    p = _arr(5, 3)
    acc = k_gravity.gravity_chunk(p[:2], p, jnp.zeros(5, jnp.float32))
    np.testing.assert_array_equal(np.asarray(acc), np.zeros((2, 3)))
