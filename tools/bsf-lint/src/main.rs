//! `bsf-lint` — static checks for the skeleton's message protocol and
//! wire schemas, run in CI via `cargo run -p bsf-lint`.
//!
//! The model checker (`bsf verify`) proves dynamic properties on a
//! small world; this linter proves the *source-level* ones that no
//! execution can witness:
//!
//! * **L1 — no tag magic outside the registry.** Every `Tag::User(0x…)`
//!   literal must live in `rust/src/transport/tags.rs`; anywhere else it
//!   can silently collide with a registered magic.
//! * **L2 — no collisions inside the registry.** Two constants with the
//!   same magic would make selective receives match the wrong message.
//! * **L3 — every protocol tag is both sent and received.** A row of the
//!   `PROTOCOL` table with no send site is dead schema; one with no
//!   receive site is a message that can only end up as an orphan.
//! * **L4 — wire-size constants match their decoder shape.** A
//!   `*_WIRE_BYTES = N * 8` constant must agree with the field count of
//!   the `type Wire = (…)` tuple it guards, or version-skew rejection
//!   breaks exactly when the wire format changes. Variable-length wire
//!   shapes (length-prefixed `Vec` payloads, e.g. pagerank's sparse
//!   reduce element) opt out with a `// lint: variable-wire` marker on
//!   the declaration or the line above it — and a fixed `*_WIRE_BYTES`
//!   constant guarding a marked shape is itself flagged as drift.
//! * **L5 — unwrap ratchet.** The count of `.unwrap()`/`.expect(` in
//!   non-test `skeleton/` + `transport/` code must not exceed the budget
//!   in `tools/bsf-lint/unwrap-ratchet.txt`. It can only go down: shrink
//!   the budget when you remove one.
//! * **L6 — no swallowed endpoint sends.** A `let _ = …send…(…, Tag…)`
//!   in non-test `skeleton/` + `transport/` code silently drops a
//!   protocol send failure — the class of bug where a dead peer's
//!   teardown error vanishes instead of landing in the run's teardown
//!   summary. Deliberate fire-and-forget sites (a spawn-failure cleanup
//!   whose original error must win) opt out with a
//!   `// lint: teardown-send` marker on the same line. Channel sends
//!   (`tx.send(…)` without a tag argument) are not protocol sends and
//!   are ignored.
//!
//! Heuristics are line-based (no rustc, no dependencies): test modules
//! are recognized by the repo-wide convention that `#[cfg(test)]` starts
//! the trailing test block of a file, and comment lines are skipped.

use std::fs;
use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// One source file, path relative to `rust/src` with `/` separators.
struct SourceFile {
    rel: String,
    text: String,
}

struct LintReport {
    violations: Vec<String>,
    notes: Vec<String>,
    files: usize,
    tags: usize,
    unwraps: usize,
}

fn main() -> ExitCode {
    // tools/bsf-lint/ → repo root is two levels up.
    let root = match Path::new(env!("CARGO_MANIFEST_DIR")).ancestors().nth(2) {
        Some(r) => r.to_path_buf(),
        None => {
            eprintln!("bsf-lint: cannot locate the repo root");
            return ExitCode::FAILURE;
        }
    };
    let src = root.join("rust").join("src");
    let sources = match load_sources(&src) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("bsf-lint: cannot read {}: {e}", src.display());
            return ExitCode::FAILURE;
        }
    };
    let budget_path = root.join("tools").join("bsf-lint").join("unwrap-ratchet.txt");
    let budget = match fs::read_to_string(&budget_path).map(|t| parse_budget(&t)) {
        Ok(Some(b)) => b,
        Ok(None) | Err(_) => {
            eprintln!("bsf-lint: missing or malformed {}", budget_path.display());
            return ExitCode::FAILURE;
        }
    };

    let report = lint(&sources, budget);
    for n in &report.notes {
        println!("bsf-lint: note: {n}");
    }
    if report.violations.is_empty() {
        println!(
            "bsf-lint: OK — {} files, {} protocol tags, unwrap budget {}/{}",
            report.files, report.tags, report.unwraps, budget
        );
        ExitCode::SUCCESS
    } else {
        for v in &report.violations {
            eprintln!("bsf-lint: error: {v}");
        }
        eprintln!("bsf-lint: {} violation(s)", report.violations.len());
        ExitCode::FAILURE
    }
}

fn load_sources(src: &Path) -> std::io::Result<Vec<SourceFile>> {
    let mut paths = Vec::new();
    collect_rs(src, &mut paths)?;
    paths.sort();
    let mut out = Vec::with_capacity(paths.len());
    for p in paths {
        let rel = p
            .strip_prefix(src)
            .unwrap_or(&p)
            .to_string_lossy()
            .replace('\\', "/");
        out.push(SourceFile { rel, text: fs::read_to_string(&p)? });
    }
    Ok(out)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// First non-comment, non-empty line of the budget file, as a count.
fn parse_budget(text: &str) -> Option<usize> {
    text.lines()
        .map(str::trim)
        .find(|l| !l.is_empty() && !l.starts_with('#'))
        .and_then(|l| l.parse().ok())
}

/// Non-test lines of a file: everything above the (conventionally
/// trailing) `#[cfg(test)]` test module. Yields `(line_no, line)`.
fn non_test_lines(text: &str) -> impl Iterator<Item = (usize, &str)> {
    text.lines()
        .take_while(|l| l.trim() != "#[cfg(test)]")
        .enumerate()
        .map(|(i, l)| (i + 1, l))
}

fn is_comment(line: &str) -> bool {
    let t = line.trim_start();
    t.starts_with("//") || t.starts_with('*')
}

/// The whole lint pass, pure over in-memory sources (fixture-testable).
fn lint(sources: &[SourceFile], budget: usize) -> LintReport {
    let mut v = Vec::new();
    let mut notes = Vec::new();

    let registry = sources.iter().find(|s| s.rel == "transport/tags.rs");
    let tag_tokens = match registry {
        Some(reg) => {
            check_registry_collisions(reg, &mut v);
            registry_tag_tokens(reg, &mut v)
        }
        None => {
            v.push("transport/tags.rs not found — the tag registry is gone".into());
            Vec::new()
        }
    };

    check_magic_outside_registry(sources, &mut v);
    check_send_recv_coverage(sources, &tag_tokens, &mut v);
    check_wire_sizes(sources, &mut v);
    check_swallowed_sends(sources, &mut v);
    let unwraps = check_unwrap_ratchet(sources, budget, &mut v, &mut notes);

    LintReport { violations: v, notes, files: sources.len(), tags: tag_tokens.len(), unwraps }
}

/// L1: `Tag::User(0x…)` literals belong in the registry, nowhere else.
fn check_magic_outside_registry(sources: &[SourceFile], v: &mut Vec<String>) {
    for s in sources {
        if s.rel == "transport/tags.rs" {
            continue;
        }
        for (no, line) in non_test_lines(&s.text) {
            if !is_comment(line) && line.contains("Tag::User(0x") {
                v.push(format!(
                    "{}:{no}: raw tag magic outside the registry — define it in \
                     transport/tags.rs and add a PROTOCOL row",
                    s.rel
                ));
            }
        }
    }
}

/// Extract every hex magic on a non-test registry line.
fn magics_in(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = line;
    while let Some(at) = rest.find("Tag::User(0x") {
        let hex: String = rest[at + "Tag::User(0x".len()..]
            .chars()
            .take_while(|c| c.is_ascii_hexdigit())
            .collect::<String>()
            .to_ascii_uppercase();
        if !hex.is_empty() {
            out.push(hex);
        }
        rest = &rest[at + "Tag::User(0x".len()..];
    }
    out
}

/// L2: two registry constants with one magic.
fn check_registry_collisions(reg: &SourceFile, v: &mut Vec<String>) {
    let mut seen: Vec<(String, usize)> = Vec::new();
    for (no, line) in non_test_lines(&reg.text) {
        if is_comment(line) || !line.contains("const ") {
            continue;
        }
        for hex in magics_in(line) {
            if let Some((_, first)) = seen.iter().find(|(h, _)| *h == hex) {
                v.push(format!(
                    "{}:{no}: tag magic 0x{hex} collides with the constant on line {first}",
                    reg.rel
                ));
            } else {
                seen.push((hex, no));
            }
        }
    }
}

/// The source tokens each PROTOCOL row is referred to by: core tags as
/// `Tag::Order`-style paths, user tags by their constant name.
fn registry_tag_tokens(reg: &SourceFile, v: &mut Vec<String>) -> Vec<String> {
    let mut out = Vec::new();
    for (no, line) in non_test_lines(&reg.text) {
        let Some(at) = line.find("name: \"") else { continue };
        let rest = &line[at + "name: \"".len()..];
        let Some(end) = rest.find('"') else { continue };
        let name = &rest[..end];
        let token = match name {
            "ORDER" => "Tag::Order".to_string(),
            "FOLD" => "Tag::Fold".to_string(),
            "EXIT" => "Tag::Exit".to_string(),
            "ABORT" => "Tag::Abort".to_string(),
            n if n.starts_with("TAG_") => n.to_string(),
            other => {
                v.push(format!(
                    "{}:{no}: PROTOCOL row \"{other}\" is neither a core tag nor TAG_*",
                    reg.rel
                ));
                continue;
            }
        };
        if out.contains(&token) {
            v.push(format!("{}:{no}: duplicate PROTOCOL row for {token}", reg.rel));
        } else {
            out.push(token);
        }
    }
    out
}

/// L3: every registered tag has a send site and a receive site in
/// non-test code outside the registry. "Send" evidence is a `send` call
/// or a `Message { tag: … }` construction; "receive" evidence is any
/// `recv` family call naming the tag.
fn check_send_recv_coverage(
    sources: &[SourceFile],
    tag_tokens: &[String],
    v: &mut Vec<String>,
) {
    for token in tag_tokens {
        let mut sent = false;
        let mut received = false;
        for s in sources {
            if s.rel == "transport/tags.rs" {
                continue;
            }
            for (_, line) in non_test_lines(&s.text) {
                if is_comment(line) || !line.contains(token.as_str()) {
                    continue;
                }
                if line.contains("send") || line.contains("tag:") || line.contains("record") {
                    sent = true;
                }
                if line.contains("recv") {
                    received = true;
                }
            }
        }
        if !sent {
            v.push(format!(
                "protocol tag {token} is never sent — dead PROTOCOL row, or its \
                 sender bypasses the registry constant"
            ));
        }
        if !received {
            v.push(format!(
                "protocol tag {token} is never received — every send of it \
                 becomes an undrained orphan"
            ));
        }
    }
}

/// The L4 escape hatch: marks a `type Wire` as variable-length by
/// design (length-prefixed `Vec` payloads), on the wire line itself or
/// the line directly above it. A marked shape is exempt from the
/// fixed-size field-count check — and conversely a `*_WIRE_BYTES`
/// constant pointing at one is drift, because no fixed byte count can
/// guard a variable payload.
const VARIABLE_WIRE_MARKER: &str = "// lint: variable-wire";

/// L4: `*_WIRE_BYTES: usize = N * 8` constants must match the leaf count
/// of the `type Wire = (…)` decoder shape in the same file; a
/// variable-length `type Wire` (anything carrying a `Vec<` or `String`)
/// must instead carry the [`VARIABLE_WIRE_MARKER`] escape hatch.
fn check_wire_sizes(sources: &[SourceFile], v: &mut Vec<String>) {
    const SCALARS: &[&str] = &[
        "usize", "u64", "u32", "u16", "u8", "f64", "f32", "i64", "i32", "i16", "i8", "bool",
    ];
    for s in sources {
        // The file's `type Wire` declarations, each with its marker and
        // variable-size verdicts (the marker may sit on the preceding
        // line, typically closing a doc comment).
        let all: Vec<(usize, &str)> = non_test_lines(&s.text).collect();
        let wires: Vec<(usize, &str, bool, bool)> = all
            .iter()
            .enumerate()
            .filter_map(|(idx, &(no, l))| {
                if is_comment(l) || !l.contains("type Wire = ") {
                    return None;
                }
                let marked = l.contains(VARIABLE_WIRE_MARKER)
                    || (idx > 0 && all[idx - 1].1.contains(VARIABLE_WIRE_MARKER));
                let variable = l.contains("Vec<") || l.contains("String");
                Some((no, l, variable, marked))
            })
            .collect();

        for &(wno, _, variable, marked) in &wires {
            if variable && !marked {
                v.push(format!(
                    "{}:{wno}: variable-length `type Wire` without the \
                     `{VARIABLE_WIRE_MARKER}` marker — the fixed-size wire check \
                     cannot guard it; annotate the shape as variable by design",
                    s.rel
                ));
            }
        }

        for &(no, line) in &all {
            if is_comment(line) || !line.contains("_WIRE_BYTES: usize") {
                continue;
            }
            let Some(eq) = line.find('=') else { continue };
            let rhs = line[eq + 1..].trim().trim_end_matches(';').trim();
            let Some(n) = rhs
                .strip_suffix("* 8")
                .and_then(|x| x.trim().parse::<usize>().ok())
            else {
                v.push(format!(
                    "{}:{no}: wire-size constant not of the checkable `N * 8` form",
                    s.rel
                ));
                continue;
            };
            match wires.first() {
                None => v.push(format!(
                    "{}:{no}: wire-size constant has no `type Wire = (…)` decoder \
                     shape in this file to check against",
                    s.rel
                )),
                Some(&(wno, _, variable, marked)) if variable || marked => {
                    v.push(format!(
                        "{}:{no}: fixed wire-size constant guards the \
                         variable-wire shape on line {wno} — a byte-count check \
                         cannot hold for length-prefixed payloads",
                        s.rel
                    ));
                }
                Some(&(wno, wl, _, _)) => {
                    let leaves = wl
                        .split(|c: char| !c.is_ascii_alphanumeric())
                        .filter(|t| SCALARS.contains(t))
                        .count();
                    if leaves != n {
                        v.push(format!(
                            "{}:{no}: wire size says {n} fields but the `type Wire` \
                             on line {wno} has {leaves} — encoder/decoder drift",
                            s.rel
                        ));
                    }
                }
            }
        }
    }
}

/// The L6 escape hatch: marks a discarded endpoint send as deliberate
/// fire-and-forget (e.g. a cleanup path whose original error must take
/// precedence over an unreachable endpoint).
const TEARDOWN_SEND_MARKER: &str = "// lint: teardown-send";

/// L6: no `let _ = …send…(…, Tag…)` in non-test `skeleton/` +
/// `transport/` code. Discarding an endpoint send's `Result` swallows a
/// protocol failure; record it (the master's teardown summary) or mark
/// the site with [`TEARDOWN_SEND_MARKER`]. The `Tag::`/`TAG_` argument
/// requirement keeps plain channel sends (`tx.send(value)`) out of
/// scope — those `Result`s signal a dropped receiver, not a peer loss.
fn check_swallowed_sends(sources: &[SourceFile], v: &mut Vec<String>) {
    for s in sources {
        if !(s.rel.starts_with("skeleton/") || s.rel.starts_with("transport/")) {
            continue;
        }
        for (no, line) in non_test_lines(&s.text) {
            if is_comment(line) || line.contains(TEARDOWN_SEND_MARKER) {
                continue;
            }
            let discards = line.contains("let _ = ");
            let endpoint_send = (line.contains(".send(") || line.contains(".send_frame("))
                && (line.contains("Tag::") || line.contains("TAG_"));
            if discards && endpoint_send {
                v.push(format!(
                    "{}:{no}: discarded endpoint send — a failed protocol send \
                     must be recorded (teardown summary) or absorbed, not \
                     swallowed; deliberate fire-and-forget sites carry \
                     `{TEARDOWN_SEND_MARKER}`",
                    s.rel
                ));
            }
        }
    }
}

/// L5: the unwrap ratchet over `skeleton/` and `transport/` non-test
/// code. Returns the observed count.
fn check_unwrap_ratchet(
    sources: &[SourceFile],
    budget: usize,
    v: &mut Vec<String>,
    notes: &mut Vec<String>,
) -> usize {
    let mut count = 0usize;
    let mut sites = Vec::new();
    for s in sources {
        if !(s.rel.starts_with("skeleton/") || s.rel.starts_with("transport/")) {
            continue;
        }
        for (no, line) in non_test_lines(&s.text) {
            if is_comment(line) {
                continue;
            }
            let hits = line.matches(".unwrap()").count() + line.matches(".expect(").count();
            if hits > 0 {
                count += hits;
                sites.push(format!("{}:{no}", s.rel));
            }
        }
    }
    if count > budget {
        v.push(format!(
            "unwrap ratchet: {count} non-test .unwrap()/.expect( sites in \
             skeleton/ + transport/, budget is {budget} (see \
             tools/bsf-lint/unwrap-ratchet.txt) — return a typed BsfError \
             instead. Sites: {}",
            sites.join(", ")
        ));
    } else if count < budget {
        notes.push(format!(
            "unwrap ratchet can tighten: {count} sites remain, budget is {budget} \
             — lower tools/bsf-lint/unwrap-ratchet.txt to {count}"
        ));
    }
    count
}

#[cfg(test)]
mod tests {
    use super::*;

    fn file(rel: &str, text: &str) -> SourceFile {
        SourceFile { rel: rel.to_string(), text: text.to_string() }
    }

    /// A minimal healthy tree: a two-row registry, one sender, one
    /// receiver, one self-consistent wire constant.
    fn clean_fixture() -> Vec<SourceFile> {
        vec![
            file(
                "transport/tags.rs",
                r#"
pub const TAG_PING: Tag = Tag::User(0x5049);
pub const PROTOCOL: &[TagSpec] = &[
    TagSpec { tag: Tag::Order, name: "ORDER", sender: Role::Master, receiver: Role::Worker, payload: "p" },
    TagSpec { tag: TAG_PING, name: "TAG_PING", sender: Role::Worker, receiver: Role::Master, payload: "empty" },
];
"#,
            ),
            file(
                "skeleton/master.rs",
                r#"
pub(crate) const REPORT_WIRE_BYTES: usize = 3 * 8;
type Wire = (usize, f64, u64);
fn step(comm: &dyn Communicator) {
    comm.send(0, Tag::Order, vec![]).ok();
    let _ = comm.recv_tags(None, &[TAG_PING]);
}
"#,
            ),
            file(
                "skeleton/worker.rs",
                r#"
fn run(comm: &dyn Communicator) {
    let _ = comm.recv(1, Tag::Order);
    comm.send(1, TAG_PING, vec![]).ok();
}
#[cfg(test)]
mod tests {
    fn in_tests_is_fine() { None::<u8>.unwrap(); let _ = Tag::User(0xDEAD); }
}
"#,
            ),
        ]
    }

    #[test]
    fn clean_tree_passes() {
        let report = lint(&clean_fixture(), 0);
        assert!(report.violations.is_empty(), "{:?}", report.violations);
        assert_eq!(report.tags, 2);
        assert_eq!(report.unwraps, 0);
    }

    #[test]
    fn colliding_magic_fails() {
        let mut fx = clean_fixture();
        fx[0].text.insert_str(
            0,
            "pub const TAG_CLASH: Tag = Tag::User(0x5049);\n",
        );
        let report = lint(&fx, 0);
        assert!(
            report.violations.iter().any(|v| v.contains("collides")),
            "{:?}",
            report.violations
        );
    }

    #[test]
    fn magic_outside_registry_fails() {
        let mut fx = clean_fixture();
        fx[1].text.push_str("const SNEAKY: Tag = Tag::User(0xBEEF);\n");
        let report = lint(&fx, 0);
        assert!(
            report.violations.iter().any(|v| v.contains("outside the registry")),
            "{:?}",
            report.violations
        );
    }

    #[test]
    fn unreceived_and_unsent_tags_fail() {
        let mut fx = clean_fixture();
        // Cut the worker file: TAG_PING loses its sender, ORDER its receiver.
        fx[2].text = String::from("fn run() {}\n");
        let report = lint(&fx, 0);
        assert!(
            report.violations.iter().any(|v| v.contains("TAG_PING is never sent")),
            "{:?}",
            report.violations
        );
        assert!(
            report.violations.iter().any(|v| v.contains("Tag::Order is never received")),
            "{:?}",
            report.violations
        );
    }

    #[test]
    fn wire_size_drift_fails() {
        let mut fx = clean_fixture();
        fx[1].text = fx[1].text.replace("3 * 8", "4 * 8");
        let report = lint(&fx, 0);
        assert!(
            report.violations.iter().any(|v| v.contains("encoder/decoder drift")),
            "{:?}",
            report.violations
        );
    }

    #[test]
    fn variable_wire_requires_the_marker() {
        let mut fx = clean_fixture();
        fx.push(file(
            "problems/sparse.rs",
            "type Wire = Vec<(u32, i64)>;\n",
        ));
        let report = lint(&fx, 0);
        assert!(
            report.violations.iter().any(|v| v.contains("variable-wire")),
            "{:?}",
            report.violations
        );
    }

    #[test]
    fn marked_variable_wire_passes_same_line_or_preceding_comment() {
        let mut fx = clean_fixture();
        fx.push(file(
            "problems/sparse.rs",
            "type Wire = Vec<(u32, i64)>; // lint: variable-wire\n",
        ));
        fx.push(file(
            "problems/sparse2.rs",
            "/// Sparse by design. // lint: variable-wire\ntype Wire = Vec<(u32, i64)>;\n",
        ));
        let report = lint(&fx, 0);
        assert!(report.violations.is_empty(), "{:?}", report.violations);
    }

    #[test]
    fn fixed_size_constant_over_variable_wire_fails() {
        let mut fx = clean_fixture();
        fx.push(file(
            "problems/sparse.rs",
            "pub(crate) const SPARSE_WIRE_BYTES: usize = 2 * 8;\n\
             type Wire = Vec<(u32, i64)>; // lint: variable-wire\n",
        ));
        let report = lint(&fx, 0);
        assert!(
            report
                .violations
                .iter()
                .any(|v| v.contains("cannot hold for length-prefixed")),
            "{:?}",
            report.violations
        );
    }

    #[test]
    fn swallowed_endpoint_send_fails() {
        let mut fx = clean_fixture();
        fx[1].text.push_str(
            "fn teardown(comm: &dyn Communicator) {\n    \
             let _ = comm.send(0, Tag::Exit, vec![]);\n}\n",
        );
        let report = lint(&fx, 0);
        assert!(
            report.violations.iter().any(|v| v.contains("discarded endpoint send")),
            "{:?}",
            report.violations
        );
        // send_frame is the same protocol surface.
        let mut fx = clean_fixture();
        fx[1].text.push_str("let _ = comm.send_frame(0, Tag::Exit, frame);\n");
        let report = lint(&fx, 0);
        assert!(
            report.violations.iter().any(|v| v.contains("discarded endpoint send")),
            "{:?}",
            report.violations
        );
    }

    #[test]
    fn marked_teardown_send_passes() {
        let mut fx = clean_fixture();
        fx[1].text.push_str(
            "let _ = comm.send(0, Tag::Exit, vec![]); // lint: teardown-send\n",
        );
        let report = lint(&fx, 0);
        assert!(report.violations.is_empty(), "{:?}", report.violations);
    }

    #[test]
    fn channel_sends_and_other_crates_are_out_of_l6_scope() {
        let mut fx = clean_fixture();
        // A plain mpsc send has no tag argument: not a protocol send.
        fx[1].text.push_str("let _ = tx.send(Event::Lost { rank });\n");
        // Outside skeleton/ + transport/, even a discarded tagged send
        // is not this lint's business.
        fx.push(file(
            "runtime/service.rs",
            "let _ = comm.send(0, Tag::Exit, vec![]);\n",
        ));
        let report = lint(&fx, 0);
        assert!(report.violations.is_empty(), "{:?}", report.violations);
    }

    #[test]
    fn unwrap_ratchet_counts_and_gates() {
        let mut fx = clean_fixture();
        fx[2].text = fx[2]
            .text
            .replace("comm.send(1, TAG_PING, vec![]).ok();", "comm.send(1, TAG_PING, vec![]).unwrap();");
        let over = lint(&fx, 0);
        assert_eq!(over.unwraps, 1);
        assert!(
            over.violations.iter().any(|v| v.contains("unwrap ratchet")),
            "{:?}",
            over.violations
        );
        let at = lint(&fx, 1);
        assert!(at.violations.is_empty(), "{:?}", at.violations);
        let under = lint(&fx, 2);
        assert!(under.notes.iter().any(|n| n.contains("can tighten")));
    }

    #[test]
    fn test_modules_and_comments_are_ignored() {
        // The clean fixture's worker test module uses .unwrap() and a raw
        // magic; neither may count. Same for commented-out code.
        let mut fx = clean_fixture();
        fx[1].text.push_str("// let bad = Tag::User(0xDEAD).unwrap();\n");
        let report = lint(&fx, 0);
        assert!(report.violations.is_empty(), "{:?}", report.violations);
        assert_eq!(report.unwraps, 0);
    }

    #[test]
    fn budget_file_parses_past_comments() {
        assert_eq!(parse_budget("# comment\n\n 5 \n"), Some(5));
        assert_eq!(parse_budget("# only comments\n"), None);
        assert_eq!(parse_budget("five"), None);
    }
}
